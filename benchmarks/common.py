"""Shared benchmark harness.

The container is a single CPU core, so every paper table is reproduced at
REDUCED scale (same code paths, smaller hidden sizes / fewer steps) while the
'Size' columns are computed at the PAPER's exact full-scale dimensions
(analytic, bit-exact).  `--quick` shrinks steps further for smoke use.

Corpora: the paper's datasets are not on disk (offline container); stand-ins
with matched vocab sizes are generated from an order-2 Markov process
(data/synth.py) or taken from this repository's own source tree ('linux-
kernel-like' code corpus).  Relative claims (ours ~ fp baseline,
BinaryConnect collapses) are meaningful on these; absolute BPC values are
corpus-dependent and reported as 'reduced-scale, synthetic corpus'.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bnlstm as BL
from repro.core.quantize import QuantSpec
from repro.data.synth import markov_bytes
from repro.data.text import ByteCorpus
from repro.train.optimizer import OptConfig
from repro.train.train_step import (make_rnn_eval, make_rnn_train_step,
                                    train_state_init)

REPO = Path(__file__).resolve().parents[1]
RESULTS = REPO / "results" / "benchmarks"

_corpora = {}


def corpus(name: str) -> ByteCorpus:
    """Matched-vocab stand-ins for the paper's corpora."""
    if name not in _corpora:
        if name == "linux":  # code corpus from this repo's own sources
            _corpora[name] = ByteCorpus.from_dir(REPO / "src", limit_bytes=2_000_000)
        else:
            vocab, seed, n = {"ptb": (50, 0, 120_000),
                              "warpeace": (87, 1, 120_000),
                              "text8": (27, 2, 120_000),
                              "words": (255, 3, 200_000)}[name]
            data = np.asarray(markov_bytes(n, vocab=vocab, seed=seed)) % 256
            _corpora[name] = ByteCorpus.from_bytes(bytes(bytearray(data)))
    return _corpora[name]


def spec_for(mode: str) -> QuantSpec:
    if mode == "fp":
        return QuantSpec(mode="none")
    return QuantSpec(mode=mode, norm="batch")


def train_rnn(corpus_name: str, mode: str, *, hidden=128, steps=150,
              batch=16, seq=48, cell="lstm", lr=3e-3, seed=0,
              eval_batches=4):
    """Train a reduced BN-LSTM/GRU with `mode` quantization; returns metrics."""
    c = corpus(corpus_name)
    cfg = BL.RNNConfig(vocab=c.vocab, d_hidden=hidden, cell=cell,
                       quant=spec_for(mode),
                       cell_norm=mode not in ("binaryconnect", "twn",
                                              "dorefa3", "dorefa4"))
    var = BL.rnn_lm_init(jax.random.PRNGKey(seed), cfg)
    st = train_state_init(var["params"], OptConfig(lr=lr),
                          jax.random.PRNGKey(seed + 1), bn_state=var["state"])
    step = jax.jit(make_rnn_train_step(cfg, OptConfig(lr=lr)))
    t0 = time.perf_counter()
    curve = []
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in c.batch("train", i, batch, seq).items()}
        st, m = step(st, b)
        if i % max(steps // 10, 1) == 0:
            curve.append(round(float(m["bpc"]), 4))
    dt = time.perf_counter() - t0
    ev = jax.jit(make_rnn_eval(cfg))
    bpcs = []
    for i in range(eval_batches):
        b = {k: jnp.asarray(v) for k, v in c.batch("valid", i, batch, seq).items()}
        bpcs.append(float(ev(st, b)["bpc"]))
    return {"mode": mode, "corpus": corpus_name, "cell": cell,
            "val_bpc": round(float(np.mean(bpcs)), 4),
            "train_curve_bpc": curve, "steps": steps, "hidden": hidden,
            "seconds": round(dt, 1), "state": st, "cfg": cfg}


def rnn_size_kb(d_in: int, hidden: int, mode: str, layers: int = 1,
                layer2_in: int | None = None) -> float:
    """Paper-style weight size (KByte = 1000 B) of the recurrent matrices."""
    bits = {"fp": 32, "binary": 1, "binaryconnect": 1, "ternary": 2,
            "twn": 2, "ttq": 2, "dorefa3": 3, "dorefa4": 4}[mode]
    n = d_in * 4 * hidden + hidden * 4 * hidden
    if layers == 2:
        n += (layer2_in or hidden) * 4 * hidden + hidden * 4 * hidden
    return round(n * bits / 8 / 1000, 1)


def write(name: str, rows, meta=None):
    RESULTS.mkdir(parents=True, exist_ok=True)
    payload = {"meta": meta or {}, "rows": rows}
    (RESULTS / f"{name}.json").write_text(json.dumps(payload, indent=1,
                                                     default=str))
    return payload


def strip(rows):
    """Drop non-serializable training artifacts before writing."""
    return [{k: v for k, v in r.items() if k not in ("state", "cfg")}
            for r in rows]
