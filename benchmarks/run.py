"""Benchmark harness — one entry per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all tables
  PYTHONPATH=src python -m benchmarks.run --quick    # smoke pass
  PYTHONPATH=src python -m benchmarks.run --only table1_char_lm roofline

Prints a compact CSV per table and writes results/benchmarks/*.json.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from benchmarks import tables as T
from benchmarks.common import REPO, RESULTS


def roofline_report(quick=False):
    """Aggregate results/dryrun/*.json into the §Roofline table."""
    outdir = REPO / "results" / "dryrun"
    rows = []
    for p in sorted(outdir.glob("*.json")) if outdir.exists() else []:
        c = json.loads(p.read_text())
        if c["status"] == "ok":
            r = c["roofline"]
            rows.append({
                "arch": c["arch"], "shape": c["shape"], "mesh": c["mesh"],
                "t_compute_s": f"{r['t_compute_s']:.3e}",
                "t_memory_s": f"{r['t_memory_s']:.3e}",
                "t_collective_s": f"{r['t_collective_s']:.3e}",
                "dominant": r["dominant"],
                "useful_flop_ratio": round(r["useful_flop_ratio"], 3),
                "roofline_fraction": round(r["roofline_fraction"], 4),
            })
        elif c["status"] == "skipped":
            rows.append({"arch": c["arch"], "shape": c["shape"],
                         "mesh": c["mesh"], "dominant": "N/A",
                         "note": c["reason"][:60]})
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "roofline_report.json").write_text(json.dumps(rows, indent=1))
    return rows


def _serve_decode(quick=False):
    from benchmarks.serve_decode import serve_decode
    return serve_decode(quick=quick)


def _serve_engine(quick=False):
    from benchmarks.serve_engine import serve_engine
    return serve_engine(quick=quick)


def _packed_kernels(quick=False):
    from benchmarks.packed_kernels import packed_kernels
    return packed_kernels(quick=quick)


def _train_rnn(quick=False):
    from benchmarks.train_rnn import train_rnn_pipeline
    return train_rnn_pipeline(quick=quick)


BENCHES = {
    "packed_kernels": _packed_kernels,
    "serve_decode": _serve_decode,
    "serve_engine": _serve_engine,
    "train_rnn": _train_rnn,
    "table1_char_lm": T.table1_char_lm,
    "table1b_convergence": T.table1b_convergence,
    "table2_text8": T.table2_text8,
    "table3_word_lm": T.table3_word_lm,
    "table4_mnist": T.table4_mnist,
    "table5_qa": T.table5_qa,
    "table6_gru": T.table6_gru,
    "table7_hardware": lambda quick=False: T.table7_hardware(),
    "fig1b_variance": T.fig1b_stochastic_variance,
    "fig2_generalization": T.fig2_generalization,
    "fig3_batch_size": T.fig3_batch_size,
    "roofline": roofline_report,
}


def _print_rows(name, rows):
    print(f"\n=== {name} ===")
    for r in rows:
        if isinstance(r, dict):
            print(",".join(f"{k}={v}" for k, v in r.items()
                           if k not in ("train_curve_bpc",)))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args(argv)

    names = args.only or list(BENCHES)
    t_all = time.time()
    failures = []
    for name in names:
        t0 = time.time()
        try:
            rows = BENCHES[name](quick=args.quick)
            _print_rows(name, rows)
            print(f"[{name}: {time.time() - t0:.1f}s]")
        except Exception as e:  # keep the harness going; report at the end
            failures.append((name, repr(e)))
            print(f"[{name}: FAILED {e!r}]")
    print(f"\ntotal {time.time() - t_all:.1f}s; "
          f"{len(names) - len(failures)}/{len(names)} benches ok")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
