"""Serving-loop benchmark: decode tok/s + packed model MB per arch.

Drives the SAME unified recurrent runtime as `launch/serve.py`
(serve/recurrent.py) — prefill a prompt batch, then a sampled decode loop —
for the paper's BN-LSTM and one transformer-pool arch, and records the
measured packed bytes (what the matmuls actually stream) and per-session
state bytes into results/benchmarks/serve_decode.json so BENCH trajectory
data accumulates across PRs.

Numbers are CPU-container throughputs at reduced scale (backend-honest
dispatch: packed weights serve through compiled dense-fallback tables on
CPU, never interpret-mode Pallas — kernels/dispatch.py): they track
*relative* regressions of the serving path, not hardware ceilings.
"""
from __future__ import annotations

import dataclasses

import jax

from benchmarks.common import write
from repro.configs import get_config
from repro.configs.rnn_paper import char_ptb, reduced
from repro.core import bnlstm as BL
from repro.core.qtensor import export_packed
from repro.core.quantize import QuantSpec
from repro.models import transformer as T
from repro.serve.recurrent import (RNNRuntime, TransformerRuntime,
                                   drive_session, serving_runtime)


def _drive(rt, vocab: int, *, batch: int, prompt: int, gen: int, seed: int = 0):
    """One warmed-up session through the SAME `drive_session` loop the
    launcher runs; returns the measured row fields.  The untimed warmup pass
    keeps jit tracing/compilation out of the recorded tok/s."""
    toks = jax.random.randint(jax.random.PRNGKey(seed), (batch, prompt),
                              0, vocab)
    _, m = drive_session(rt, toks, vocab, gen=gen, temperature=0.8, top_k=8,
                         seed=seed + 1, warmup=True)
    fp, packed = rt.param_nbytes()
    return {
        "prefill_tok_s": round(m["prefill_tok_s"], 1),
        "decode_tok_s": round(m["decode_tok_s"], 1),
        "fp32_model_MB": round(fp / 1e6, 3),
        "packed_model_MB": round(packed / 1e6, 3),
        "compression_x": round(fp / packed, 2),
        "state_MB": round(m["state_nbytes"] / 1e6, 3),
    }


def serve_decode(quick: bool = False):
    gen = 8 if quick else 32
    prompt = 8 if quick else 16
    batch = 2 if quick else 4
    rows = []

    # --- the paper's BN-LSTM, packed ternary, fused decode kernel ----------
    cfg = reduced(char_ptb())
    cfg = dataclasses.replace(cfg, quant=QuantSpec(mode="ternary", norm="batch"))
    var = BL.rnn_lm_init(jax.random.PRNGKey(0), cfg)
    qvar = {"params": BL.export_packed_rnn(var["params"], cfg),
            "state": var["state"]}
    rt = serving_runtime(cfg, qvar)
    assert isinstance(rt, RNNRuntime)
    rows.append({"arch": "rnn-paper", "quant": "ternary",
                 **_drive(rt, cfg.vocab, batch=batch, prompt=prompt, gen=gen)})

    # --- one transformer-pool arch through the same loop -------------------
    tcfg = get_config("qwen3-0.6b").reduced().with_quant(
        QuantSpec(mode="ternary", norm="channel"))
    params = export_packed(T.model_init(jax.random.PRNGKey(0), tcfg), tcfg.quant)
    trt = serving_runtime(tcfg, params)
    assert isinstance(trt, TransformerRuntime)
    rows.append({"arch": "qwen3-0.6b", "quant": "ternary",
                 **_drive(trt, tcfg.vocab, batch=batch, prompt=prompt,
                          gen=max(gen // 4, 4))})

    write("serve_decode", rows, meta={"quick": quick,
                                      "backend": jax.default_backend(),
                                      "note": "reduced scale, interpret-mode "
                                              "kernels on CPU"})
    return rows
