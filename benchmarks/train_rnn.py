"""The train->export->serve pipeline as a recorded benchmark.

Delegates to `repro.launch.train --arch rnn-paper --pipeline` (the one
command the README documents): train the paper's BN-LSTM on the char-PTB
stand-in corpus with a REAL mid-run SIGTERM + restart, assert the resumed
run is bit-identical to an uninterrupted one, export the trained masters to
packed ternary with frozen BN statistics, prove ServeEngine byte parity
against the sequential oracle, and measure the trained-master speculative
accept rate.  The launcher writes results/benchmarks/train_rnn.json itself;
this wrapper returns the rows so `benchmarks.run` prints them in the table.
"""
from __future__ import annotations

import tempfile


def train_rnn_pipeline(quick: bool = False):
    from repro.launch import train as LT

    argv = ["--arch", "rnn-paper", "--reduced", "--pipeline",
            "--batch", "16", "--seq", "32", "--steps", "300",
            "--eval-every", "50", "--ckpt-every", "50", "--lr", "2e-3"]
    if quick:
        argv.append("--quick")
    with tempfile.TemporaryDirectory(prefix="bench_train_rnn_") as d:
        return LT.main(argv + ["--ckpt-dir", d])
