"""Continuous-batching engine benchmark: aggregate tok/s, occupancy, latency.

Replays a deterministic mixed-length Poisson workload (launch/serve.py's
`synth_traffic`) through `ServeEngine` for the paper's packed BN-LSTM and
one transformer-pool arch, and records aggregate decode tok/s, slot
occupancy %, p50/p95 per-request latency, TTFT p50/p95 (time to the FIRST
sampled token — real under chunked in-slot prefill) and the max
decode-stall (prefill chunks one admission ran between decode ticks) into
results/benchmarks/serve_engine.json so the BENCH trajectory accumulates
across PRs.  The tick-trace count rides along as a regression tripwire for
the compile-once invariant (it must be 1), and the stall count for the
no-head-of-line-blocking invariant (<= 1 chunk).

The speculative section (DESIGN.md §9) DRAINS one fixed greedy workload
twice over the same fp masters — plain decoding vs packed-ternary-draft
speculation — and records the acceptance rate and both throughputs
(realtime=False: drain tok/s measures decode capacity, not the offered
arrival rate).  The spec row's agg_tok_s beating the plain row's is the
paper's draft-model thesis measured end to end.

The shared-prefix section replays chat-style traffic (one system prompt,
many user tails) through a prefix-state cache (DESIGN.md §10) and records
the hit rate plus TTFT p50/p95 for cache-hit vs cache-miss requests — the
RNN family's O(1) carried state makes a hit one spliced row copy instead of
a full prefix re-prefill.  Rows whose pass/fail win condition was actually
enforced carry `"asserted": true`; --quick runs record `"asserted": false`
so the bench table cannot present unasserted wins as wins.

The mesh section (`--mesh`, DESIGN.md §12) sweeps the data-sharded engine
over D in {1, 2, 4, 8} under `XLA_FLAGS=--xla_force_host_platform_device_
count=8` — each point a fresh subprocess, because the flag must be set
before jax initializes, and the SAME forced-8 runtime hosts the D=1
baseline so the comparison isolates sharding, not device-count plumbing.
Each point drains the same workload through D× the slots and records
aggregate tok/s plus scaling efficiency vs D=1.  Forced host "devices" are
threads of ONE CPU core in this container, so quick mode records
`asserted: false`; a full run on real parallel hardware asserts D=4 >= 2x.
Mesh rows MERGE into serve_engine.json (replacing only prior mesh rows) so
the sweep composes with the main benchmark's history.

Numbers are CPU-container throughputs at reduced scale (backend-honest
dispatch: packed weights serve through compiled dense-fallback tables on
CPU, never interpret-mode Pallas — kernels/dispatch.py): they track
*relative* regressions of the scheduling path, not hardware ceilings.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys

import jax
import numpy as np

from benchmarks.common import RESULTS, write
from repro.configs import get_config
from repro.configs.rnn_paper import char_ptb, reduced
from repro.core import bnlstm as BL
from repro.core.qtensor import export_packed
from repro.core.quantize import QuantSpec
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine
from repro.serve.recurrent import serving_runtime, speculative_draft
from repro.launch.serve import synth_traffic


def _drive(rt, vocab: int, *, slots: int, requests: int, rate: float,
           prompt: int, gen: int, seed: int = 0, chunk: int = 8) -> dict:
    ctx = prompt + gen
    eng = ServeEngine(rt, vocab, slots=slots, max_context=ctx,
                      prefill_chunk=chunk)
    reqs = synth_traffic(vocab, requests=requests, rate=rate,
                         prompt_len=prompt, gen=gen, temperature=0.8,
                         top_k=8, seed=seed)
    # warm every declared chunk bucket + the tick, so the recorded numbers
    # measure the serving path rather than XLA compilation
    eng.warm([np.asarray(r.prompt).size for r in reqs])

    _, m = eng.run(reqs, realtime=True)
    assert m["tick_traces"] == 1, "occupancy changes retraced the tick"
    assert m["max_decode_stall_ticks"] <= 1, \
        "an admission ran more than one prefill chunk between decode ticks"
    return {
        "slots": slots,
        "prefill_chunk": chunk,
        "requests": m["requests"],
        "agg_tok_s": round(m["agg_tok_s"], 1),
        "occupancy_pct": round(100 * m["occupancy"], 1),
        "p50_latency_ms": round(1e3 * m["p50_latency_s"], 1),
        "p95_latency_ms": round(1e3 * m["p95_latency_s"], 1),
        "ttft_p50_ms": round(1e3 * m["ttft_p50_s"], 1),
        "ttft_p95_ms": round(1e3 * m["ttft_p95_s"], 1),
        "max_decode_stall_ticks": m["max_decode_stall_ticks"],
        "ticks": m["ticks"],
        "tick_traces": m["tick_traces"],
        "prefill_traces": m["prefill_traces"],
    }


def _best_of(engines, reqs, trials: int) -> list:
    """Noise-resistant drain measurement: INTERLEAVE the engines trial by
    trial (so a machine-speed phase hits both comparands equally) and keep
    each engine's fastest run.  The single-core container's scheduler
    noise is one-sided (runs only ever get slower), so min-wall is the
    robust estimator; tokens and acceptance are identical across trials
    (greedy + fixed seeds)."""
    best = [None] * len(engines)
    for _ in range(trials):
        for i, eng in enumerate(engines):
            _, m = eng.run([dataclasses.replace(r) for r in reqs],
                           realtime=False)
            if best[i] is None or m["agg_tok_s"] > best[i]["agg_tok_s"]:
                best[i] = m
    return best


def _spec_rows(quick: bool) -> list:
    """Drain ONE greedy workload through plain fp decoding and through
    packed-draft speculation over the same masters: acceptance rate and
    the emitted-tok/s win, recorded per PR.

    The masters are BRIEFLY TRAINED with ternary quantization in the loop
    (benchmarks/common.train_rnn) rather than random-init: the paper's
    premise — and the acceptance-rate driver — is that a net trained with
    quantized weights tracks its fp twin closely.  Random init measures
    quantization noise, not the method (acceptance ~0.45 vs ~0.75).

    slots=1: speculation's serving win is PER-STREAM decode latency (the
    sequential-bottleneck regime it was invented for).  At full batch on
    this container the comparison is compute-bound — the draft serves
    through the CPU dense-fallback tables (backend-honest dispatch), so a
    draft step costs about what a target step costs and the
    aggregate-throughput rows above remain the batch story."""
    from benchmarks.common import train_rnn

    # the spec configuration is the SAME in quick and full mode (the drain
    # itself is sub-second; 120 training steps ~11 s buy acceptance ~0.75
    # vs ~0.6) — quick only trims trials and skips the hard asserts
    requests = 6
    prompt = 6
    gen = 48
    slots = 1
    spec_k = 4
    trials = 3 if quick else 5

    tr = train_rnn("ptb", "ternary", hidden=64, steps=120, batch=16, seq=32)
    cfg = dataclasses.replace(tr["cfg"], quant=QuantSpec(mode="none"))
    rt = serving_runtime(cfg, {"params": tr["state"].params,
                               "state": tr["state"].bn_state})
    draft = speculative_draft(rt, mode="ternary")

    ctx = prompt + gen
    reqs = synth_traffic(cfg.vocab, requests=requests, rate=1e9,
                         prompt_len=prompt, gen=gen, temperature=0.0,
                         top_k=0, seed=0)
    lens = [np.asarray(r.prompt).size for r in reqs]
    plain = ServeEngine(rt, cfg.vocab, slots=slots, max_context=ctx,
                        prefill_chunk=8)
    spec = ServeEngine(rt, cfg.vocab, slots=slots, max_context=ctx,
                       prefill_chunk=8, draft=draft, spec_k=spec_k)
    plain.warm(lens)
    spec.warm(lens)
    mp, ms = _best_of([plain, spec], reqs, trials)
    assert mp["tick_traces"] == 1 and ms["spec_traces"] == 1

    def row(m):
        return {
            "slots": slots, "requests": m["requests"],
            "gen_tokens": m["gen_tokens"],
            "agg_tok_s": round(m["agg_tok_s"], 1),
            "ticks": m["ticks"],
        }

    rows = [
        {"arch": "rnn-paper", "quant": "none", "mode": "plain-drain",
         **row(mp), "tick_traces": mp["tick_traces"]},
        {"arch": "rnn-paper", "quant": "none+ternary-draft",
         "mode": "spec-drain", **row(ms), "spec_k": ms["spec_k"],
         "accept_rate": round(ms["accept_rate"], 3),
         "drafted_tokens": ms["drafted_tokens"],
         "draft_tok_s": round(ms["draft_tok_s"], 1),
         "spec_traces": ms["spec_traces"],
         "speedup_vs_plain": round(ms["agg_tok_s"] / mp["agg_tok_s"], 2),
         # the recorded row SAYS whether the contract was enforced: a
         # --quick run records asserted=false so the bench table can never
         # present an unasserted result as a verified one
         "asserted": not quick},
    ]
    # what the full run ASSERTS is the machine-independent win: trained
    # masters keep acceptance high (the paper's fp-tracking premise) and
    # speculation collapses the tick count by ~1+accept*k.  The wall-clock
    # ratio is RECORDED, not asserted — on this container the draft runs
    # the compiled dense CPU fallback (a draft step costs about what a
    # target step costs), so emitted-tok/s parity is the expected floor
    # and the ratio only exceeds 1 when per-tick dispatch overhead
    # dominates; asserting it made the recorded run hostage to host
    # scheduler state (observed flipping between 1.00 and 1.41 across
    # otherwise-identical idle runs, both engine versions).
    if not quick:
        assert ms["accept_rate"] > 0.6, \
            "trained-master draft acceptance collapsed"
        assert ms["ticks"] * 2 < mp["ticks"], \
            "speculation did not reduce decode rounds"
    return rows


def _prefix_rows(quick: bool) -> list:
    """Shared-prefix chat workload (DESIGN.md §10): the same system prompt
    repeated across requests with unique user tails, served through a
    prefix-state cache.  Records the hit rate and TTFT p50/p95 for HIT
    requests (prefix spliced: one row copy + the tail chunk) vs MISS
    requests (cold full prefill) on the paper's packed-ternary LSTM — the
    O(1)-carried-state advantage measured end to end.  Requests run one at
    a time on a 1-slot engine so TTFT isolates prefill cost from queueing."""
    from repro.serve.prefixcache import PrefixCache

    chunk = 8
    system_len = 24 if quick else 48     # 3 / 6 chunk boundaries deep
    tail, gen = 4, 8
    n_sys = 2 if quick else 3            # distinct system prompts (misses)
    reps = 3 if quick else 6             # shared-prefix repeats (hits)

    cfg = reduced(char_ptb())
    cfg = dataclasses.replace(cfg, quant=QuantSpec(mode="ternary",
                                                   norm="batch"))
    var = BL.rnn_lm_init(jax.random.PRNGKey(0), cfg)
    qvar = {"params": BL.export_packed_rnn(var["params"], cfg),
            "state": var["state"]}
    rt = serving_runtime(cfg, qvar)
    eng = ServeEngine(rt, cfg.vocab, slots=1,
                      max_context=system_len + tail + gen,
                      prefill_chunk=chunk, prefix_cache=PrefixCache(64 << 20))
    eng.warm([system_len + tail])

    rng = np.random.default_rng(0)
    # warm the cache's device paths too (gather/narrow on the cold pass,
    # widen/splice on the hit) with a throwaway system prompt, so measured
    # TTFTs — especially the hit-side p95 — exclude one-time compilation
    wsys = rng.integers(0, cfg.vocab, size=system_len)
    for r in range(2):
        eng.run([Request(prompt=np.concatenate(
                     [wsys, rng.integers(0, cfg.vocab, size=tail)]
                 ).astype(np.int32), max_tokens=1, temperature=0.0,
                 seed=r)], realtime=False)
    warm_stats = {k: getattr(eng.prefix_cache, k)
                  for k in ("hits", "misses", "hit_tokens")}
    for k, v in warm_stats.items():  # keep recorded counters measurement-only
        setattr(eng.prefix_cache, k, 0)

    comps = []
    for s in range(n_sys):
        system = rng.integers(0, cfg.vocab, size=system_len)
        for r in range(1 + reps):        # 1 cold + `reps` shared-prefix
            prompt = np.concatenate(
                [system, rng.integers(0, cfg.vocab, size=tail)])
            cs, m = eng.run([Request(prompt=prompt.astype(np.int32),
                                     max_tokens=gen, temperature=0.8,
                                     top_k=8, seed=100 * s + r)],
                            realtime=False)
            comps.extend(cs)
    assert m["tick_traces"] == 1 and m["splice_traces"] == 1
    hit = sorted(c.ttft_s for c in comps if c.cached_tokens > 0)
    miss = sorted(c.ttft_s for c in comps if c.cached_tokens == 0)
    assert len(miss) == n_sys and len(hit) == n_sys * reps, \
        "every shared-prefix repeat must hit the cache"
    pct = lambda xs, p: xs[min(len(xs) - 1, int(p * len(xs)))]
    s = eng.prefix_cache.stats()
    asserted = not quick
    if asserted:
        # the acceptance bar: resuming from a spliced state row must be
        # measurably faster to first token than re-prefilling the prefix
        assert pct(hit, 0.5) < pct(miss, 0.5), \
            f"prefix-cache hit TTFT {pct(hit, 0.5)} not below miss " \
            f"TTFT {pct(miss, 0.5)}"
    return [{
        "arch": "rnn-paper", "quant": "ternary", "mode": "shared-prefix",
        "requests": len(comps), "system_tokens": system_len,
        "prefill_chunk": chunk,
        "hit_rate": round(s["hit_rate"], 3),
        "hit_tokens": s["hit_tokens"],
        "cache_entries": s["entries"], "cache_bytes": s["bytes"],
        "ttft_hit_p50_ms": round(1e3 * pct(hit, 0.5), 1),
        "ttft_hit_p95_ms": round(1e3 * pct(hit, 0.95), 1),
        "ttft_miss_p50_ms": round(1e3 * pct(miss, 0.5), 1),
        "ttft_miss_p95_ms": round(1e3 * pct(miss, 0.95), 1),
        "ttft_speedup_p50": round(pct(miss, 0.5) / max(pct(hit, 0.5), 1e-9),
                                  2),
        "splice_traces": m["splice_traces"],
        "asserted": asserted,
    }]


def _mesh_point(d: int, quick: bool) -> dict:
    """One sweep point, run INSIDE a forced-8-device subprocess: the
    paper's packed-ternary LSTM on a data=d mesh (d=1: a plain meshless
    engine on the same forced-8 runtime — the honest baseline), slots
    scaled d-fold, draining one fixed workload."""
    from repro.launch.mesh import make_serve_mesh
    from repro.configs.rnn_paper import char_ptb, reduced

    requests = 8 if quick else 24
    prompt = 8
    gen = 8 if quick else 16
    slots = (2 if quick else 4) * d
    trials = 1 if quick else 3

    cfg = reduced(char_ptb())
    cfg = dataclasses.replace(cfg, quant=QuantSpec(mode="ternary",
                                                   norm="batch"))
    var = BL.rnn_lm_init(jax.random.PRNGKey(0), cfg)
    qvar = {"params": BL.export_packed_rnn(var["params"], cfg),
            "state": var["state"]}
    rt = serving_runtime(cfg, qvar)
    eng = ServeEngine(rt, cfg.vocab, slots=slots, max_context=prompt + gen,
                      prefill_chunk=8,
                      mesh=None if d == 1 else make_serve_mesh(f"data={d}"))
    reqs = synth_traffic(cfg.vocab, requests=requests, rate=1e9,
                         prompt_len=prompt, gen=gen, temperature=0.8,
                         top_k=8, seed=0)
    eng.warm([np.asarray(r.prompt).size for r in reqs])
    best = None
    for _ in range(trials):
        _, m = eng.run([dataclasses.replace(r) for r in reqs],
                       realtime=False)
        if best is None or m["agg_tok_s"] > best["agg_tok_s"]:
            best = m
    assert best["tick_traces"] == 1, "sharding retraced the tick"
    return {"arch": "rnn-paper", "quant": "ternary", "mode": "mesh-drain",
            "data_shards": d, "forced_devices": len(jax.devices()),
            "slots": slots, "requests": best["requests"],
            "gen_tokens": best["gen_tokens"],
            "agg_tok_s": round(best["agg_tok_s"], 1),
            "ticks": best["ticks"], "tick_traces": best["tick_traces"]}


def mesh_rows(quick: bool = False) -> list:
    """The D-sweep driver: one subprocess per point (XLA's forced device
    count is fixed at jax init, so points cannot share a process), scaling
    efficiency computed against the D=1 point, rows merged into
    serve_engine.json in place of any previous mesh rows."""
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(here, "src"), env.get("PYTHONPATH", "")])
    rows = []
    for d in (1, 2, 4, 8):
        cmd = [sys.executable, "-m", "benchmarks.serve_engine",
               "--mesh-child", str(d)] + (["--quick"] if quick else [])
        r = subprocess.run(cmd, env=env, cwd=here, capture_output=True,
                           text=True)
        if r.returncode != 0:
            raise RuntimeError(f"mesh point data={d} failed:\n"
                               + r.stdout[-2000:] + r.stderr[-2000:])
        line = [l for l in r.stdout.splitlines()
                if l.startswith("MESH-ROW ")][-1]
        rows.append(json.loads(line[len("MESH-ROW "):]))
        print(rows[-1])
    base = rows[0]["agg_tok_s"]
    for r in rows:
        r["scaling_x"] = round(r["agg_tok_s"] / base, 2)
        r["efficiency"] = round(r["scaling_x"] / r["data_shards"], 2)
        r["asserted"] = not quick
    if not quick:
        d4 = next(r for r in rows if r["data_shards"] == 4)
        assert d4["agg_tok_s"] >= 2 * base, (
            f"data=4 drain {d4['agg_tok_s']} tok/s did not reach 2x the "
            f"D=1 baseline {base} tok/s on the same workload")

    path = RESULTS / "serve_engine.json"
    payload = (json.loads(path.read_text()) if path.exists()
               else {"meta": {}, "rows": []})
    payload["rows"] = [r for r in payload["rows"]
                       if r.get("mode") != "mesh-drain"] + rows
    payload["meta"]["mesh_note"] = (
        "mesh-drain rows: forced host devices are threads of one CPU core "
        "in this container — efficiency measures scheduler/SPMD overhead "
        "there, not parallel speedup; full mode on real devices asserts "
        "data=4 >= 2x")
    RESULTS.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(payload, indent=1, default=str))
    return rows


def serve_engine(quick: bool = False, spec_only: bool = False):
    if spec_only:
        return _spec_rows(quick)
    requests = 6 if quick else 24
    prompt = 8 if quick else 16
    gen = 6 if quick else 24
    slots = 2 if quick else 4
    rate = 8.0 if quick else 16.0
    rows = []

    # --- the paper's BN-LSTM, packed ternary, fused decode kernel ----------
    cfg = reduced(char_ptb())
    cfg = dataclasses.replace(cfg, quant=QuantSpec(mode="ternary", norm="batch"))
    var = BL.rnn_lm_init(jax.random.PRNGKey(0), cfg)
    qvar = {"params": BL.export_packed_rnn(var["params"], cfg),
            "state": var["state"]}
    rows.append({"arch": "rnn-paper", "quant": "ternary",
                 **_drive(serving_runtime(cfg, qvar), cfg.vocab, slots=slots,
                          requests=requests, rate=rate, prompt=prompt,
                          gen=gen)})

    # --- one transformer-pool arch under the same scheduler ----------------
    tcfg = get_config("qwen3-0.6b").reduced().with_quant(
        QuantSpec(mode="ternary", norm="channel"))
    params = export_packed(T.model_init(jax.random.PRNGKey(0), tcfg),
                           tcfg.quant)
    rows.append({"arch": "qwen3-0.6b", "quant": "ternary",
                 **_drive(serving_runtime(tcfg, params), tcfg.vocab,
                          slots=max(slots // 2, 2),
                          requests=max(requests // 2, 4), rate=rate,
                          prompt=prompt, gen=max(gen // 2, 4))})

    # --- speculative decoding: packed drafts vs plain fp, same masters -----
    rows.extend(_spec_rows(quick))

    # --- shared-prefix chat traffic through the prefix-state cache ---------
    rows.extend(_prefix_rows(quick))

    write("serve_engine", rows, meta={"quick": quick,
                                      "backend": jax.default_backend(),
                                      "note": "reduced scale; backend-honest "
                                              "dispatch (CPU: compiled dense "
                                              "fallback, no interpret-mode "
                                              "Pallas); Poisson mixed-length "
                                              "traffic replay; spec rows "
                                              "drain one greedy workload "
                                              "(realtime=False)"})
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--spec", action="store_true",
                    help="run only the speculative-vs-plain drain comparison "
                         "(does not rewrite serve_engine.json)")
    ap.add_argument("--mesh", action="store_true",
                    help="sweep the data-sharded engine over D in {1,2,4,8} "
                         "forced host devices; merges mesh rows into "
                         "serve_engine.json without touching other rows")
    ap.add_argument("--mesh-child", type=int, default=0, metavar="D",
                    help=argparse.SUPPRESS)  # internal: one sweep point
    args = ap.parse_args()
    if args.mesh_child:
        print("MESH-ROW " + json.dumps(_mesh_point(args.mesh_child,
                                                   args.quick)))
    elif args.mesh:
        mesh_rows(quick=args.quick)
    else:
        for r in serve_engine(quick=args.quick, spec_only=args.spec):
            print(r)
