"""Continuous-batching engine benchmark: aggregate tok/s, occupancy, latency.

Replays a deterministic mixed-length Poisson workload (launch/serve.py's
`synth_traffic`) through `ServeEngine` for the paper's packed BN-LSTM and
one transformer-pool arch, and records aggregate decode tok/s, slot
occupancy %, p50/p95 per-request latency, TTFT p50/p95 (time to the FIRST
sampled token — real under chunked in-slot prefill) and the max
decode-stall (prefill chunks one admission ran between decode ticks) into
results/benchmarks/serve_engine.json so the BENCH trajectory accumulates
across PRs.  The tick-trace count rides along as a regression tripwire for
the compile-once invariant (it must be 1), and the stall count for the
no-head-of-line-blocking invariant (<= 1 chunk).

Numbers are CPU-container interpret-mode throughputs at reduced scale: they
track *relative* regressions of the scheduling path, not hardware ceilings.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import write
from repro.configs import get_config
from repro.configs.rnn_paper import char_ptb, reduced
from repro.core import bnlstm as BL
from repro.core.qtensor import export_packed
from repro.core.quantize import QuantSpec
from repro.models import transformer as T
from repro.serve.engine import ServeEngine
from repro.serve.recurrent import serving_runtime
from repro.launch.serve import synth_traffic


def _drive(rt, vocab: int, *, slots: int, requests: int, rate: float,
           prompt: int, gen: int, seed: int = 0, chunk: int = 8) -> dict:
    ctx = prompt + gen
    eng = ServeEngine(rt, vocab, slots=slots, max_context=ctx,
                      prefill_chunk=chunk)
    reqs = synth_traffic(vocab, requests=requests, rate=rate,
                         prompt_len=prompt, gen=gen, temperature=0.8,
                         top_k=8, seed=seed)
    # warm every declared chunk bucket + the tick, so the recorded numbers
    # measure the serving path rather than XLA compilation
    eng.warm([np.asarray(r.prompt).size for r in reqs])

    _, m = eng.run(reqs, realtime=True)
    assert m["tick_traces"] == 1, "occupancy changes retraced the tick"
    assert m["max_decode_stall_ticks"] <= 1, \
        "an admission ran more than one prefill chunk between decode ticks"
    return {
        "slots": slots,
        "prefill_chunk": chunk,
        "requests": m["requests"],
        "agg_tok_s": round(m["agg_tok_s"], 1),
        "occupancy_pct": round(100 * m["occupancy"], 1),
        "p50_latency_ms": round(1e3 * m["p50_latency_s"], 1),
        "p95_latency_ms": round(1e3 * m["p95_latency_s"], 1),
        "ttft_p50_ms": round(1e3 * m["ttft_p50_s"], 1),
        "ttft_p95_ms": round(1e3 * m["ttft_p95_s"], 1),
        "max_decode_stall_ticks": m["max_decode_stall_ticks"],
        "ticks": m["ticks"],
        "tick_traces": m["tick_traces"],
        "prefill_traces": m["prefill_traces"],
    }


def serve_engine(quick: bool = False):
    requests = 6 if quick else 24
    prompt = 8 if quick else 16
    gen = 6 if quick else 24
    slots = 2 if quick else 4
    rate = 8.0 if quick else 16.0
    rows = []

    # --- the paper's BN-LSTM, packed ternary, fused decode kernel ----------
    cfg = reduced(char_ptb())
    cfg = dataclasses.replace(cfg, quant=QuantSpec(mode="ternary", norm="batch"))
    var = BL.rnn_lm_init(jax.random.PRNGKey(0), cfg)
    qvar = {"params": BL.export_packed_rnn(var["params"], cfg),
            "state": var["state"]}
    rows.append({"arch": "rnn-paper", "quant": "ternary",
                 **_drive(serving_runtime(cfg, qvar), cfg.vocab, slots=slots,
                          requests=requests, rate=rate, prompt=prompt,
                          gen=gen)})

    # --- one transformer-pool arch under the same scheduler ----------------
    tcfg = get_config("qwen3-0.6b").reduced().with_quant(
        QuantSpec(mode="ternary", norm="channel"))
    params = export_packed(T.model_init(jax.random.PRNGKey(0), tcfg),
                           tcfg.quant)
    rows.append({"arch": "qwen3-0.6b", "quant": "ternary",
                 **_drive(serving_runtime(tcfg, params), tcfg.vocab,
                          slots=max(slots // 2, 2),
                          requests=max(requests // 2, 4), rate=rate,
                          prompt=prompt, gen=max(gen // 2, 4))})

    write("serve_engine", rows, meta={"quick": quick,
                                      "backend": jax.default_backend(),
                                      "note": "reduced scale, interpret-mode "
                                              "kernels on CPU; Poisson "
                                              "mixed-length traffic replay"})
    return rows
