"""Packed-kernel microbenchmark (DESIGN.md §11): accumulation-only GEMV vs
fp32 dense GEMV across H x B x {binary, ternary}, the analytic weight-bytes
ratio those shapes move, and launches-per-tick for the paper-LSTM decode tick.

Backend-honest per the dispatch policy (kernels/dispatch.py): on CPU the
packed number times the jit-compiled XLA lowering of `accumulate_gemv`
(the same mul-free select/add program the Pallas kernel runs — NEVER
interpret-mode Pallas, which would be thousands of times slower than the
serving path actually is); on tpu/gpu it times the compiled `packed_gemv`
launch.  The `path` field records which one was measured.  The bytes ratio
is analytic (16x ternary, 32x binary — codes only, no scale on the RNN
path) and asserted >= 12x, the paper's memory-bandwidth claim.

Launches-per-tick is counted the way the engine counts tick_traces: trace
one whole decode tick, diff the dispatch launch counter — 1 for the fused
packed tick, 0 for the CPU dense-tables fallback.
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp

from benchmarks.common import write
from repro.core import bnlstm as BL
from repro.core.qtensor import QTensor
from repro.core.quantize import BINARY_GROUP, TERNARY_GROUP, QuantSpec
from repro.kernels import dispatch
from repro.kernels.packed_matmul import accumulate_gemv, packed_gemv


def _time_us(fn, *args, iters: int = 20) -> float:
    """Median wall micro-seconds of fn(*args) after a compile+warm pass."""
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def _gemv_rows(quick: bool):
    """x (B, H) @ W (H, 4H) — the decode-tick gate-matrix shape."""
    hs = (256,) if quick else (256, 512, 1024)
    bs = (1,) if quick else (1, 4, 8)
    on_cpu = dispatch.backend() == "cpu"
    rows = []
    for h in hs:
        for b in bs:
            k, n = h, 4 * h
            key = jax.random.PRNGKey(h + b)
            w = jax.random.normal(key, (k, n), jnp.float32) * 0.02
            x = jax.random.normal(jax.random.fold_in(key, 1), (b, k),
                                  jnp.float32)
            fp = jax.jit(lambda a, m: a @ m)
            t_fp = _time_us(fp, x, w)
            for mode in ("ternary", "binary"):
                qt = QTensor.from_master(w, mode)
                if on_cpu:
                    path = "xla_accumulate"  # honest: compiled, not interpret
                    pk = jax.jit(functools.partial(accumulate_gemv, mode=mode))
                    t_packed = _time_us(pk, x, qt.codes)
                else:
                    path = "pallas_gemv"
                    pk = jax.jit(functools.partial(packed_gemv, k=k, mode=mode))
                    t_packed = _time_us(pk, x, qt.codes)
                group = TERNARY_GROUP if mode == "ternary" else BINARY_GROUP
                fp_bytes = k * n * 4
                packed_bytes = (k // group) * n * 4
                ratio = fp_bytes / packed_bytes
                assert ratio >= 12, (
                    f"weight-bytes ratio {ratio:.1f}x < the paper's 12x claim")
                rows.append({
                    "bench": "gemv", "mode": mode, "H": h, "B": b,
                    "path": path,
                    "t_packed_us": round(t_packed, 1),
                    "t_fp_us": round(t_fp, 1),
                    "packed_vs_fp": round(t_fp / t_packed, 3),
                    "weight_bytes_fp": fp_bytes,
                    "weight_bytes_packed": packed_bytes,
                    "bytes_ratio": round(ratio, 1),
                })
    return rows


def _tick_rows(quick: bool):
    """Launches traced per whole decode tick, exactly as engine.tick counts
    them: 1 fused packed launch, 0 on the dense CPU fallback."""
    cfg = BL.RNNConfig(vocab=64, d_hidden=128 if quick else 256, n_layers=2,
                       cell="lstm", quant=QuantSpec(mode="ternary",
                                                    norm="batch"))
    var = BL.rnn_lm_init(jax.random.PRNGKey(0), cfg)
    qvar = {"params": BL.export_packed_rnn(var["params"], cfg),
            "state": var["state"]}
    st = BL.rnn_state_init(cfg, 4, per_slot=True)
    tok = jnp.zeros((4,), jnp.int32)
    live = jnp.ones((4,), bool)
    rows = []
    for name, dense in (("packed_whole_tick", False), ("dense_fallback", True)):
        tb = BL.rnn_decode_tables(qvar, cfg, dense=dense)
        n = dispatch.traced_launches(
            lambda t, s: BL.rnn_decode_step(
                qvar, t, cfg, s, tables=tb, live=live,
                interpret=True if not dense else None), tok, st)
        want = 0 if dense else 1
        assert n == want, f"{name}: traced {n} launches per tick, want {want}"
        rows.append({"bench": "tick", "tables": name, "cell": cfg.cell,
                     "layers": cfg.n_layers, "H": cfg.d_hidden,
                     "launches_per_tick": n})
    return rows


def packed_kernels(quick: bool = False):
    rows = _gemv_rows(quick) + _tick_rows(quick)
    write("packed_kernels", rows,
          meta={"backend": dispatch.backend(), "quick": quick,
                "note": "CPU rows time compiled XLA accumulate_gemv, "
                        "never interpret-mode Pallas"})
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    for r in packed_kernels(quick=args.quick):
        print(",".join(f"{k}={v}" for k, v in r.items()))
