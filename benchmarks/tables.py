"""One benchmark per paper table/figure (reduced scale; exact-size columns).

Each function returns printable rows and writes results/benchmarks/<name>.json.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import bnlstm as BL
from repro.core import quantize as Q
from repro.core.quantize import QuantSpec
from repro.data.synth import seq_mnist_like
from repro.train.optimizer import OptConfig, opt_init, opt_update


# --- Table 1: char-level BPC, LSTM, quantized vs baselines -------------------

def table1_char_lm(quick=False):
    steps = 60 if quick else 200
    modes = ["fp", "ternary", "binary", "binaryconnect"]
    extra = [] if quick else ["twn", "dorefa3"]
    rows = []
    for corpus, d_in, hid_full in (("ptb", 50, 1000), ("linux", None, 512)):
        vocab = C.corpus(corpus).vocab
        for mode in modes + (extra if corpus == "ptb" else []):
            r = C.train_rnn(corpus, mode, steps=steps)
            r["size_kb_full"] = C.rnn_size_kb(vocab if d_in is None else d_in,
                                              hid_full, mode)
            rows.append(r)
    out = C.strip(rows)
    C.write("table1_char_lm", out,
            meta={"note": "reduced hidden=128; size column at paper dims"})
    return out


# --- Table 1b: convergence-scale comparison -----------------------------------

def table1b_convergence(quick=False):
    """Closer to the paper's operating point (seq 100 as in Appendix C,
    wider LSTM, longer training): the regime where BinaryConnect's missing
    output normalization starts to bite while BN-ternary tracks fp.  The
    short-horizon table1 rows deliberately keep this separate — at 200 steps
    the BinaryConnect failure mode has not kicked in yet (documented in
    EXPERIMENTS.md §Repro)."""
    steps = 80 if quick else 500
    rows = []
    for mode in ("fp", "ternary", "binaryconnect"):
        r = C.train_rnn("ptb", mode, hidden=256, steps=steps, seq=100,
                        batch=16, lr=2e-3)
        r["size_kb_full"] = C.rnn_size_kb(50, 1000, mode)
        rows.append(r)
    out = C.strip(rows)
    C.write("table1b_convergence", out)
    return out


# --- Table 2: Text8 (size-dominated) -----------------------------------------

def table2_text8(quick=False):
    steps = 60 if quick else 150
    rows = []
    for mode in ("fp", "ternary", "binary"):
        r = C.train_rnn("text8", mode, steps=steps)
        n = 27 * 4 * 2000 + 2000 * 4 * 2000  # paper: LSTM-2000 on text8
        bits = {"fp": 32, "ternary": 2, "binary": 1}[mode]
        r["size_mb_full"] = round(n * bits / 8 / 1e6, 1)
        rows.append(r)
    out = C.strip(rows)
    C.write("table2_text8", out)
    return out


# --- Table 3: word-level PTB (perplexity) ------------------------------------

def table3_word_lm(quick=False):
    steps = 60 if quick else 180
    rows = []
    for name, hidden_red, hidden_full, layers in (("small", 96, 300, 1),
                                                  ("medium", 160, 650, 1)):
        for mode in ("fp", "ternary", "binary", "binaryconnect"):
            r = C.train_rnn("words", mode, hidden=hidden_red, steps=steps,
                            seq=35)
            r["model"] = name
            r["val_ppl"] = round(float(np.exp(r["val_bpc"] * np.log(2))), 2)
            r["size_kb_full"] = C.rnn_size_kb(10000, hidden_full, mode,
                                              layers=layers)
            rows.append(r)
    out = C.strip(rows)
    C.write("table3_word_lm", out,
            meta={"note": "byte-corpus stand-in for 10k-word PTB; ppl=2^bpc"})
    return out


# --- Table 4: sequential MNIST ------------------------------------------------

def table4_mnist(quick=False):
    steps = 80 if quick else 300
    side = 16  # reduced 16x16 'pixels' (paper: 28x28)
    rows = []
    for mode in ("fp", "ternary", "binary", "binaryconnect"):
        cfg = BL.RNNConfig(vocab=2, d_hidden=64, quant=C.spec_for(mode),
                           cell_norm=mode != "binaryconnect")
        var = BL.rnn_lm_init(jax.random.PRNGKey(0), cfg)
        params = var["params"]
        # classification head on the LAST hidden state (paper: LSTM-100 +
        # softmax classifier over the final state)
        params["cls"] = {
            "W": 0.1 * jax.random.normal(jax.random.PRNGKey(5),
                                         (cfg.d_hidden, 10)),
            "b": jnp.zeros((10,))}
        opt_cfg = OptConfig(lr=2e-3)
        opt = opt_init(params, opt_cfg)
        bn_state = var["state"]

        def step(params, opt, bn_state, batch, rng):
            def lf(p):
                tokens = (batch["pixels"][..., 0] > 0.5).astype(jnp.int32)
                hs, new_bn = BL.rnn_lm_apply(
                    {"params": {"layers": p["layers"], "head": p["head"]},
                     "state": bn_state}, tokens, cfg, training=True, rng=rng,
                    return_state=True, features_only=True)   # (B, T, H)
                out = hs[:, -1] @ p["cls"]["W"] + p["cls"]["b"]
                onehot = jax.nn.one_hot(batch["labels"], 10)
                l = -jnp.mean(jnp.sum(jax.nn.log_softmax(out) * onehot, -1))
                return l, (new_bn, out)

            (l, (new_bn, out)), g = jax.value_and_grad(lf, has_aux=True)(params)
            params, opt, _ = opt_update(g, opt, params, opt_cfg)
            params = dict(params)
            inner = {"layers": params["layers"], "head": params["head"]}
            inner = BL.clip_masters(inner, cfg)
            params.update(inner)
            acc = jnp.mean((jnp.argmax(out, -1) == batch["labels"]))
            return params, opt, new_bn, l, acc

        jstep = jax.jit(step)
        rng = jax.random.PRNGKey(1)
        accs = []
        for i in range(steps):
            b = seq_mnist_like(i, 32, side=side)
            b = {k: jnp.asarray(v) for k, v in b.items()}
            rng, sub = jax.random.split(rng)
            params, opt, bn_state, l, acc = jstep(params, opt, bn_state, b, sub)
            accs.append(float(acc))
        n = 1 * 4 * 100 + 100 * 4 * 100  # paper dims: LSTM-100, 1-dim input
        bits = {"fp": 32, "ternary": 2, "binary": 1, "binaryconnect": 1}[mode]
        rows.append({"mode": mode,
                     "final_train_acc": round(float(np.mean(accs[-10:])), 3),
                     "size_kb_full": round(n * bits / 8 / 1000, 1),
                     "ops_kops_full": round(2 * n / 1000, 1)})
    C.write("table4_mnist", rows)
    return rows


# --- Table 5: question answering (attentive-reader-lite) ----------------------

def table5_qa(quick=False):
    """Synthetic cloze: the answer token appears right after a marker in the
    document; an attention readout over BN-GRU encodings must find it.
    Exercises the paper's claim that the technique survives attention +
    bidirectional recurrent encoders."""
    steps = 80 if quick else 250
    vocab, seq, B = 40, 24, 32
    MARK = vocab - 1

    def make_batch(step):
        rng = np.random.default_rng(1000 + step)
        doc = rng.integers(0, vocab - 1, size=(B, seq))
        pos = rng.integers(0, seq - 1, size=B)
        ans = rng.integers(0, vocab - 1, size=B)
        doc[np.arange(B), pos] = MARK
        doc[np.arange(B), pos + 1] = ans
        return {"doc": doc.astype(np.int32), "ans": ans.astype(np.int32)}

    rows = []
    for mode in ("fp", "ternary", "binary", "binaryconnect"):
        cfg = BL.RNNConfig(vocab=vocab, d_hidden=48, cell="gru",
                           quant=C.spec_for(mode), cell_norm=False)
        var = BL.rnn_lm_init(jax.random.PRNGKey(0), cfg)
        params = var["params"]
        params["qa"] = {"Wm": jnp.zeros((vocab, 48)),  # (logit-space readout)
                        "w": jnp.zeros((48,)),
                        "Wa": 0.01 * jax.random.normal(jax.random.PRNGKey(2),
                                                       (vocab, vocab))}
        opt_cfg = OptConfig(lr=3e-3)
        opt = opt_init(params, opt_cfg)
        bn_state = var["state"]

        def step(params, opt, bn_state, batch, rng):
            def lf(p):
                enc, new_bn = BL.rnn_lm_apply(
                    {"params": {"layers": p["layers"], "head": p["head"]},
                     "state": bn_state}, batch["doc"], cfg, training=True,
                    rng=rng, return_state=True)           # (B, T, vocab)
                m = jnp.tanh(enc @ p["qa"]["Wm"])          # (B, T, 48)
                s = jax.nn.softmax(m @ p["qa"]["w"], axis=-1)
                r = jnp.einsum("bt,btv->bv", s, enc)
                out = r @ p["qa"]["Wa"]
                onehot = jax.nn.one_hot(batch["ans"], vocab)
                l = -jnp.mean(jnp.sum(jax.nn.log_softmax(out) * onehot, -1))
                return l, (new_bn, out)

            (l, (new_bn, out)), g = jax.value_and_grad(lf, has_aux=True)(params)
            params, opt, _ = opt_update(g, opt, params, opt_cfg)
            inner = BL.clip_masters({"layers": params["layers"],
                                     "head": params["head"]}, cfg)
            params = dict(params)
            params.update(inner)
            acc = jnp.mean((jnp.argmax(out, -1) == batch["ans"]))
            return params, opt, new_bn, l, acc

        jstep = jax.jit(step)
        rng = jax.random.PRNGKey(1)
        accs = []
        for i in range(steps):
            b = {k: jnp.asarray(v) for k, v in make_batch(i).items()}
            rng, sub = jax.random.split(rng)
            params, opt, bn_state, l, acc = jstep(params, opt, bn_state, b, sub)
            accs.append(float(acc))
        rows.append({"mode": mode,
                     "final_acc": round(float(np.mean(accs[-10:])), 3),
                     "size_mb_full": round(
                         (256 * 4 * 256 * 2 + 2 * 120000 * 256) *
                         {"fp": 32, "ternary": 2, "binary": 1,
                          "binaryconnect": 1}[mode] / 8 / 1e6, 1)})
    C.write("table5_qa", rows)
    return rows


# --- Table 6: GRU char-level ---------------------------------------------------

def table6_gru(quick=False):
    steps = 60 if quick else 200
    rows = []
    for mode in ("fp", "ternary", "binary"):
        r = C.train_rnn("ptb", mode, cell="gru", steps=steps)
        n = 50 * 3 * 1000 + 1000 * 3 * 1000
        bits = {"fp": 32, "ternary": 2, "binary": 1}[mode]
        r["size_kb_full"] = round(n * bits / 8 / 1000, 1)
        rows.append(r)
    out = C.strip(rows)
    C.write("table6_gru", out)
    return out


# --- Table 7: hardware (analytic ASIC model + TPU translation) ----------------

def table7_hardware():
    """Paper's ASIC numbers (from Table 7, as the published reference) next
    to this framework's TPU-side translation computed from our dry-run."""
    asic = [
        {"design": "low-power", "precision": "fp12", "mac": 100,
         "gops": 80, "area_mm2": 2.56, "power_mw": 336},
        {"design": "low-power", "precision": "binary", "mac": 100,
         "gops": 80, "area_mm2": 0.24, "power_mw": 37},
        {"design": "low-power", "precision": "ternary", "mac": 100,
         "gops": 80, "area_mm2": 0.42, "power_mw": 61},
        {"design": "high-speed", "precision": "fp12", "mac": 100,
         "gops": 80, "area_mm2": 2.56, "power_mw": 336},
        {"design": "high-speed", "precision": "binary", "mac": 1000,
         "gops": 800, "area_mm2": 2.54, "power_mw": 347},
        {"design": "high-speed", "precision": "ternary", "mac": 500,
         "gops": 400, "area_mm2": 2.16, "power_mw": 302},
    ]
    # derived claims the implementation must honor
    derived = {
        "speedup_binary": 800 / 80, "speedup_ternary": 400 / 80,
        "area_saving_binary": round(2.56 / 0.24, 1),
        "power_saving_binary": round(336 / 37, 1),
        "mem_bw_saving_binary": 32 * 12 / 32,   # 12-bit fp vs 1-bit
        "mem_bw_saving_ternary": 12 / 2,
    }
    # TPU translation: weight-stream bytes per decode token (qwen3-1.7b)
    from repro.configs import get_config
    from repro.launch.roofline import analytic_hbm_bytes
    from repro.configs.shapes import ShapeSpec
    cfg = get_config("qwen3-1.7b")
    sh = ShapeSpec("decode", 1024, 1, "decode")
    tpu = {}
    for name, bits in (("bf16", 16), ("ternary_packed", 2),
                       ("binary_packed", 1)):
        tpu[name] = analytic_hbm_bytes(cfg, sh, 1, weight_bits=bits)
    tpu_row = {"decode_hbm_bytes": {k: round(v / 1e6, 1) for k, v in tpu.items()},
               "bandwidth_amplification_ternary":
                   round(tpu["bf16"] / tpu["ternary_packed"], 2),
               "bandwidth_amplification_binary":
                   round(tpu["bf16"] / tpu["binary_packed"], 2)}
    C.write("table7_hardware", asic, meta={"derived": derived, "tpu": tpu_row})
    return asic + [derived, tpu_row]


# --- figures -------------------------------------------------------------------

def fig1b_stochastic_variance(quick=False):
    """Variance of prediction quality under STOCHASTIC ternary sampling
    (paper Fig. 1b: negligible)."""
    r = C.train_rnn("ptb", "ternary", steps=40 if quick else 150)
    st, cfg = r["state"], r["cfg"]
    c = C.corpus("ptb")
    b = {k: jnp.asarray(v) for k, v in c.batch("valid", 0, 16, 48).items()}

    def eval_stochastic(rng):
        loss, _ = BL.lm_loss({"params": st.params, "state": st.bn_state},
                             b["tokens"], b["targets"], cfg, training=True,
                             rng=rng)
        return loss / jnp.log(2.0)

    f = jax.jit(eval_stochastic)
    n = 40 if quick else 200
    bpcs = np.array([float(f(jax.random.PRNGKey(i))) for i in range(n)])
    out = {"mean_bpc": round(float(bpcs.mean()), 4),
           "std_bpc": round(float(bpcs.std()), 5),
           "deterministic_bpc": r["val_bpc"], "n_samples": n}
    C.write("fig1b_variance", [out])
    return [out]


def fig2_generalization(quick=False):
    """Eval BPC at sequence lengths beyond training (paper Fig. 2b)."""
    r = C.train_rnn("ptb", "ternary", steps=60 if quick else 200, seq=32)
    st, cfg = r["state"], r["cfg"]
    from repro.train.train_step import make_rnn_eval
    ev = jax.jit(make_rnn_eval(cfg), static_argnames=())
    c = C.corpus("ptb")
    rows = []
    for seq in (32, 64, 128):
        b = {k: jnp.asarray(v) for k, v in c.batch("valid", 0, 8, seq).items()}
        loss, _ = BL.lm_loss({"params": st.params, "state": st.bn_state},
                             b["tokens"], b["targets"], cfg, training=False)
        rows.append({"seq": seq, "bpc": round(float(loss / jnp.log(2.0)), 4)})
    rows.append({"train_curve_bpc": r["train_curve_bpc"]})
    C.write("fig2_generalization", rows)
    return rows


def fig3_batch_size(quick=False):
    """Prediction quality vs training batch size (paper Fig. 3: BN-quantized
    models need a non-trivial batch for stable statistics)."""
    steps = 60 if quick else 150
    rows = []
    for batch in (2, 8, 32):
        r = C.train_rnn("ptb", "ternary", steps=steps, batch=batch)
        rows.append({"batch": batch, "val_bpc": r["val_bpc"]})
    C.write("fig3_batch_size", rows)
    return rows
