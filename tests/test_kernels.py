"""Pallas kernels vs pure-jnp oracles (interpret mode): shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantize as Q
from repro.kernels import ops, ref


@pytest.mark.parametrize("mode", ["ternary", "binary"])
@pytest.mark.parametrize("mkn", [(4, 256, 384), (128, 512, 512),
                                 (1, 1024, 256), (67, 320, 136), (8, 64, 8)])
def test_packed_matmul_matches_ref(mode, mkn):
    M, K, N = mkn
    kw, kx, ku = jax.random.split(jax.random.PRNGKey(M * K + N), 3)
    w = jax.random.normal(kw, (K, N)) * 0.02
    u = jax.random.uniform(ku, (K, N))
    alpha = 0.05
    wp = ops.quantize_pack(w, u, alpha, mode=mode)
    wp_ref = (ref.quantize_pack_ternary_ref if mode == "ternary"
              else ref.quantize_pack_binary_ref)(w, u, alpha)
    np.testing.assert_array_equal(np.asarray(wp), np.asarray(wp_ref))

    x = jax.random.normal(kx, (M, K), jnp.float32)
    y = ops.packed_matmul(x, wp, K, alpha, mode=mode)
    y_ref = (ref.ternary_matmul_ref if mode == "ternary"
             else ref.binary_matmul_ref)(x, wp, K, alpha)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_packed_matmul_dtypes(dtype):
    M, K, N = 16, 256, 128
    kw, kx, ku = jax.random.split(jax.random.PRNGKey(0), 3)
    w = jax.random.normal(kw, (K, N)) * 0.02
    u = jax.random.uniform(ku, (K, N))
    wp = ops.quantize_pack(w, u, 0.05, mode="ternary")
    x = jax.random.normal(kx, (M, K)).astype(dtype)
    y = ops.packed_matmul(x, wp, K, 0.05, mode="ternary")
    y_ref = ref.ternary_matmul_ref(x, wp, K, 0.05)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_packed_matmul_batched_input():
    kw, kx, ku = jax.random.split(jax.random.PRNGKey(1), 3)
    w = jax.random.normal(kw, (128, 64)) * 0.02
    u = jax.random.uniform(ku, w.shape)
    wp = ops.quantize_pack(w, u, 0.05, mode="ternary")
    x = jax.random.normal(kx, (2, 3, 128))
    y = ops.packed_matmul(x, wp, 128, 0.05, mode="ternary")
    assert y.shape == (2, 3, 64)
    y2 = ops.packed_matmul(x.reshape(6, 128), wp, 128, 0.05, mode="ternary")
    np.testing.assert_allclose(np.asarray(y).reshape(6, 64), np.asarray(y2),
                               rtol=1e-5)


@pytest.mark.parametrize("mode,group", [("ternary", 16), ("binary", 32)])
def test_qtensor_qmatmul_end_to_end(mode, group):
    """qmatmul(x, QTensor) == deterministic quantization matmul; 16x/32x bytes."""
    from repro.core.qtensor import QTensor

    K, N = 512, 256
    w = jax.random.normal(jax.random.PRNGKey(2), (K, N)) * 0.02
    alpha = Q.glorot_alpha(K, N)
    qt = QTensor.from_master(w, mode, alpha)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, K))
    y = ops.qmatmul(x, qt)
    qfn = Q.ternarize_deterministic if mode == "ternary" else Q.binarize_deterministic
    y_ref = x @ qfn(w, alpha)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    assert qt.nbytes == K * N * 4 // group


def test_quantize_pack_fused_equals_two_step():
    """Fused kernel == (stochastic quantize, then pack) composition."""
    w = jax.random.normal(jax.random.PRNGKey(4), (256, 128)) * 0.03
    u = jax.random.uniform(jax.random.PRNGKey(5), w.shape)
    a = 0.04
    fused = ops.quantize_pack(w, u, a, mode="ternary")
    q = Q.ternarize_stochastic(w, u, a) / a
    np.testing.assert_array_equal(np.asarray(fused),
                                  np.asarray(Q.pack_ternary(q)))
