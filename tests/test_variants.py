"""Perf-variant correctness: the beyond-paper optimizations must not change
semantics (packed comms: bit-exact; parallel block: well-formed training)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.qlinear import quantize_tree
from repro.core.quantize import QuantSpec
from repro.data.synth import token_stream
from repro.models import transformer as T
from repro.train.optimizer import OptConfig
from repro.train.train_step import make_train_step, train_state_init


def test_packed_comms_is_bit_exact():
    """The pack -> (would-be gather) -> unpack round-trip in quantize_tree
    must reproduce the plain quantized weights exactly."""
    spec = QuantSpec(mode="ternary", norm="channel")
    spec_packed = dataclasses.replace(spec, packed_comms=True)
    params = {"Wq": jax.random.normal(jax.random.PRNGKey(0), (64, 32)) * 0.02,
              "stack": {"Wup": jax.random.normal(jax.random.PRNGKey(1),
                                                 (3, 48, 16)) * 0.02}}
    rng = jax.random.PRNGKey(2)
    q_plain = quantize_tree(params, spec, rng, compute_dtype=jnp.float32)
    q_packed = quantize_tree(params, spec_packed, rng,
                             compute_dtype=jnp.float32)
    for a, b in zip(jax.tree.leaves(q_plain), jax.tree.leaves(q_packed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_packed_comms_gradients_flow_to_masters():
    spec = QuantSpec(mode="ternary", norm="channel", packed_comms=True)
    w = jax.random.normal(jax.random.PRNGKey(0), (32, 16)) * 0.02

    def loss(params):
        q = quantize_tree(params, spec, jax.random.PRNGKey(1))
        return jnp.sum(q["Wq"] * 2.0)

    g = jax.grad(loss)({"Wq": w})["Wq"]
    np.testing.assert_allclose(np.asarray(g), 2.0, rtol=1e-6)


def test_packed_comms_skips_non_multiple_k():
    """K not divisible by the group: falls back to plain cast, no crash."""
    spec = QuantSpec(mode="ternary", norm="channel", packed_comms=True)
    params = {"Wq": jax.random.normal(jax.random.PRNGKey(0), (30, 8)) * 0.02}
    q = quantize_tree(params, spec, jax.random.PRNGKey(1))
    vals = np.unique(np.round(np.asarray(q["Wq"]) /
                              np.max(np.abs(np.asarray(q["Wq"]))), 4))
    assert len(vals) <= 3


def test_parallel_block_trains():
    cfg = dataclasses.replace(get_config("qwen3-0.6b").reduced(),
                              parallel_block=True)
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    opt = OptConfig(lr=1e-3)
    st = train_state_init(params, opt, jax.random.PRNGKey(1))
    step = jax.jit(make_train_step(cfg, opt))
    losses = []
    for i in range(4):
        b = {k: jnp.asarray(v) for k, v in
             token_stream(i, 4, 32, cfg.vocab).items()}
        st, m = step(st, b)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()


def test_dots_remat_policy_matches_full():
    """Remat policy changes scheduling, not values."""
    cfg = get_config("qwen3-0.6b").reduced()
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    b = {k: jnp.asarray(v) for k, v in token_stream(0, 2, 16, cfg.vocab).items()}

    def loss(cfg):
        l, _ = T.lm_loss(params, b, cfg, training=True,
                         rng=jax.random.PRNGKey(1))
        return float(l)

    l_full = loss(cfg)
    l_dots = loss(dataclasses.replace(cfg, remat_policy="dots"))
    assert l_full == pytest.approx(l_dots, rel=1e-5)


def test_serve_param_pspec_drops_fsdp_axes():
    from jax.sharding import PartitionSpec as P
    from repro.launch.sharding import serve_param_pspec
    from repro.runtime import abstract_mesh
    import jax.tree_util as jtu
    mesh = abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    path = (jtu.DictKey("Wq"),)
    leaf = jax.ShapeDtypeStruct((4096, 4096), jnp.float32)
    assert serve_param_pspec(path, leaf, mesh) == P(None, "model")


def test_quantize_embeddings_flag():
    """Default (paper): embed/head stay fp.  With the flag, they quantize."""
    spec = QuantSpec(mode="ternary", norm="channel")
    params = {"embed": jax.random.normal(jax.random.PRNGKey(0), (64, 16)),
              "head": jax.random.normal(jax.random.PRNGKey(1), (16, 64)),
              "Wq": jax.random.normal(jax.random.PRNGKey(2), (16, 16)) * 0.02}
    rng = jax.random.PRNGKey(3)
    q = quantize_tree(params, spec, rng)
    assert len(np.unique(np.asarray(q["embed"]))) > 3      # untouched
    spec_e = dataclasses.replace(spec, quantize_embeddings=True)
    q = quantize_tree(params, spec_e, rng)
    for name in ("embed", "head", "Wq"):
        assert len(np.unique(np.asarray(q[name]))) <= 3, name

    cfg = dataclasses.replace(get_config("qwen3-0.6b").reduced(),
                              quant=spec_e)
    p = T.model_init(jax.random.PRNGKey(0), cfg)
    logits, _ = T.forward(p, jnp.zeros((2, 8), jnp.int32), cfg,
                          training=True, rng=jax.random.PRNGKey(1))
    assert bool(jnp.isfinite(logits).all())
