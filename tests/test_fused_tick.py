"""Whole-tick fused decode (DESIGN.md §11): single-launch parity, the
static mul-freeness proof, and the backend-honest dispatch policy.

Covers: fused whole-tick == per-layer unfused serving math to 1e-5 for
LSTM+GRU x {binary, ternary} x B in {1, 4}; live-mask dead-row freeze
(bit-exact); ragged-K padding (H not a multiple of the 128 lane tile or the
pack group); the accumulation-only GEMV jaxpr contains zero
mul/dot_general; one traced decode tick dispatches EXACTLY one Pallas
launch (and the CPU dense fallback dispatches zero); interpret-mode Pallas
== dense CPU fallback == ref path on the same inputs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bnlstm as BL
from repro.core.qtensor import QTensor
from repro.core.quantize import QuantSpec
from repro.kernels import dispatch, ops, ref
from repro.kernels.packed_matmul import accumulate_gemv


def _rnn_cfg(cell, mode="ternary", hidden=40, layers=2, vocab=50):
    return BL.RNNConfig(vocab=vocab, d_hidden=hidden, n_layers=layers,
                        cell=cell, quant=QuantSpec(mode=mode, norm="batch"))


def _packed_vars(cfg, seed=0):
    var = BL.rnn_lm_init(jax.random.PRNGKey(seed), cfg)
    # walk the BN running stats off init so the folded affines are
    # non-trivial (catches scale/shift fold bugs the init stats would hide)
    keys = iter(jax.random.split(jax.random.PRNGKey(seed + 1), 64))
    var["state"] = jax.tree.map(
        lambda a: a + 0.1 * jax.random.normal(next(keys), a.shape),
        var["state"])
    return {"params": BL.export_packed_rnn(var["params"], cfg),
            "state": var["state"]}


def _walked_state(qvar, cfg, tables, B):
    """A per-slot state a few real steps off zero."""
    st = BL.rnn_state_init(cfg, B, per_slot=True)
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, 3), 0, cfg.vocab)
    for i in range(3):
        _, st = BL.rnn_decode_step(qvar, toks[:, i], cfg, st, tables=tables,
                                   fused=False)
    return st


# --- whole-tick parity -------------------------------------------------------


@pytest.mark.parametrize("B", [1, 4])
@pytest.mark.parametrize("mode", ["ternary", "binary"])
@pytest.mark.parametrize("cell", ["lstm", "gru"])
def test_fused_tick_matches_unfused(cell, mode, B):
    cfg = _rnn_cfg(cell, mode)
    qvar = _packed_vars(cfg)
    tables = BL.rnn_decode_tables(qvar, cfg, dense=False)
    st = _walked_state(qvar, cfg, tables, B)
    tok = jax.random.randint(jax.random.PRNGKey(9), (B,), 0, cfg.vocab)
    lg_f, st_f = BL.rnn_decode_step(qvar, tok, cfg, st, tables=tables,
                                    fused=True, interpret=True)
    lg_u, st_u = BL.rnn_decode_step(qvar, tok, cfg, st, tables=tables,
                                    fused=False)
    np.testing.assert_allclose(np.asarray(lg_f), np.asarray(lg_u), atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_f.h), np.asarray(st_u.h),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_f.c), np.asarray(st_u.c),
                               atol=1e-5)


@pytest.mark.parametrize("cell", ["lstm", "gru"])
def test_fused_tick_live_mask_freezes_dead_rows(cell):
    cfg = _rnn_cfg(cell)
    qvar = _packed_vars(cfg)
    tables = BL.rnn_decode_tables(qvar, cfg, dense=False)
    st = _walked_state(qvar, cfg, tables, 4)
    tok = jnp.array([3, 7, 1, 9], jnp.int32)
    live = jnp.array([True, False, True, False])
    lg_f, st_f = BL.rnn_decode_step(qvar, tok, cfg, st, tables=tables,
                                    fused=True, interpret=True, live=live)
    lg_u, st_u = BL.rnn_decode_step(qvar, tok, cfg, st, tables=tables,
                                    fused=False, live=live)
    # dead rows: BIT-exact freeze of h, c and pos inside the kernel
    for dead in (1, 3):
        np.testing.assert_array_equal(np.asarray(st_f.h[:, dead]),
                                      np.asarray(st.h[:, dead]))
        np.testing.assert_array_equal(np.asarray(st_f.c[:, dead]),
                                      np.asarray(st.c[:, dead]))
        assert int(st_f.pos[dead]) == int(st.pos[dead])
    # live rows step identically to the unfused masked step
    for alive in (0, 2):
        np.testing.assert_allclose(np.asarray(st_f.h[:, alive]),
                                   np.asarray(st_u.h[:, alive]), atol=1e-5)
        np.testing.assert_allclose(np.asarray(lg_f[alive]),
                                   np.asarray(lg_u[alive]), atol=1e-5)


@pytest.mark.parametrize("hidden", [40, 136])
def test_fused_tick_ragged_k_padding(hidden):
    """H neither a lane-tile (128) nor pack-group multiple: pad codes and
    pad activation lanes must contribute exactly nothing across layers."""
    cfg = _rnn_cfg("lstm", "binary", hidden=hidden)  # binary: pad code = -1
    qvar = _packed_vars(cfg)
    tables = BL.rnn_decode_tables(qvar, cfg, dense=False)
    st = _walked_state(qvar, cfg, tables, 2)
    tok = jnp.array([5, 11], jnp.int32)
    lg_f, st_f = BL.rnn_decode_step(qvar, tok, cfg, st, tables=tables,
                                    fused=True, interpret=True)
    lg_u, st_u = BL.rnn_decode_step(qvar, tok, cfg, st, tables=tables,
                                    fused=False)
    np.testing.assert_allclose(np.asarray(lg_f), np.asarray(lg_u), atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_f.h), np.asarray(st_u.h),
                               atol=1e-5)


def test_fused_tick_greedy_argmax_matches_logits():
    cfg = _rnn_cfg("lstm")
    qvar = _packed_vars(cfg)
    tables = BL.rnn_decode_tables(qvar, cfg, dense=False)
    st = _walked_state(qvar, cfg, tables, 4)
    tok = jnp.array([3, 7, 1, 9], jnp.int32)
    logits, _, _, greedy = ops.fused_decode_tick(
        tok, st.h, st.c, tables[0]["tick"], cell="lstm", mode="ternary",
        vocab=cfg.vocab, interpret=True)
    np.testing.assert_array_equal(np.asarray(greedy),
                                  np.asarray(jnp.argmax(logits, axis=-1)))


# --- the static mul-freeness proof (tier-1) ----------------------------------


@pytest.mark.parametrize("mode,group", [("ternary", 16), ("binary", 32)])
def test_gemv_jaxpr_is_accumulation_only(mode, group):
    """The packed GEMV consumes decoded weights with ZERO multiplies: its
    jaxpr (recursively) contains no mul/dot_general — the paper's
    replace-every-MAC-with-an-accumulation claim as a compiler fact."""
    x = jnp.ones((8, 64), jnp.float32)
    codes = jnp.asarray(np.random.default_rng(0).integers(
        0, 2**32, (64 // group, 128), dtype=np.uint32))
    dispatch.assert_accumulation_only(accumulate_gemv, x, codes, mode=mode)


def test_accumulation_assertion_catches_multiplies():
    x = jnp.ones((4, 8))
    with pytest.raises(AssertionError, match="multiply"):
        dispatch.assert_accumulation_only(lambda a: a @ a.T, x)
    with pytest.raises(AssertionError, match="multiply"):
        dispatch.assert_accumulation_only(lambda a: a * 2.0, x)


def test_accumulate_gemv_matches_dense_oracle():
    kx, kw, ku = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(kx, (4, 128))
    for mode in ("ternary", "binary"):
        w = jax.random.normal(kw, (128, 256)) * 0.02
        u = jax.random.uniform(ku, w.shape)
        wp = ops.quantize_pack(w, u, 0.05, mode=mode)
        y = accumulate_gemv(x, wp, mode=mode)
        fn = (ref.ternary_matmul_ref if mode == "ternary"
              else ref.binary_matmul_ref)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(fn(x, wp, 128, 1.0)),
                                   rtol=1e-5, atol=1e-5)


# --- launches per tick (counted like tick_traces) ----------------------------


def test_decode_tick_is_one_pallas_launch():
    """Tracing one packed decode tick dispatches EXACTLY one Pallas launch;
    the dense-table tick dispatches ZERO (the CPU serving path never runs
    interpret-mode Pallas)."""
    cfg = _rnn_cfg("lstm")
    qvar = _packed_vars(cfg)
    st = BL.rnn_state_init(cfg, 4, per_slot=True)
    tok = jnp.zeros((4,), jnp.int32)
    live = jnp.ones((4,), bool)

    packed_tb = BL.rnn_decode_tables(qvar, cfg, dense=False)
    n = dispatch.traced_launches(
        lambda t, s: BL.rnn_decode_step(qvar, t, cfg, s, tables=packed_tb,
                                        live=live, interpret=True), tok, st)
    assert n == 1, f"packed decode tick traced {n} launches, want 1"

    dense_tb = BL.rnn_decode_tables(qvar, cfg, dense=True)
    n = dispatch.traced_launches(
        lambda t, s: BL.rnn_decode_step(qvar, t, cfg, s, tables=dense_tb,
                                        live=live), tok, st)
    assert n == 0, f"dense decode tick traced {n} launches, want 0"


def test_cpu_runtime_defaults_to_dense_tables():
    """Backend-honest dispatch: on CPU a packed runtime serves through dense
    tables (no tick artifact, no Pallas); elsewhere through packed ones."""
    from repro.serve.recurrent import RNNRuntime

    cfg = _rnn_cfg("lstm")
    qvar = _packed_vars(cfg)
    rt = RNNRuntime(cfg, qvar)
    on_cpu = dispatch.backend() == "cpu"
    assert rt._dense_tables == on_cpu
    assert ("tick" in rt.tables[0]) == (not on_cpu)


# --- backend parity guard ----------------------------------------------------


@pytest.mark.parametrize("mode", ["ternary", "binary"])
def test_qmatmul_backend_parity(mode):
    """interpret-mode Pallas == dense fallback == ref oracle on the same
    inputs, so the dispatch policy can never silently diverge per backend.
    On CPU `interpret=None` takes the dense fallback and `interpret=True`
    the emulated kernel; on tpu/gpu both run the compiled kernel."""
    K, N = 256, 128
    w = jax.random.normal(jax.random.PRNGKey(2), (K, N)) * 0.02
    qt = QTensor.from_master(w, mode, 0.05)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, K))

    y_default = ops.qmatmul(x, qt)                    # backend policy path
    y_pallas = ops.qmatmul(x, qt, interpret=dispatch.backend() == "cpu")
    y_dense = jnp.dot(x, qt.dequantize(jnp.float32))  # the CPU fallback math
    fn = (ref.ternary_matmul_ref if mode == "ternary"
          else ref.binary_matmul_ref)
    y_ref = fn(x, qt.codes, K, qt.alpha)

    for name, y in (("default", y_default), ("pallas", y_pallas),
                    ("dense", y_dense)):
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-5, atol=1e-5, err_msg=name)


def test_tick_backend_parity():
    """The fused tick (interpret Pallas) == dense-tables unfused step ==
    packed-tables unfused step, one triangle per backend."""
    cfg = _rnn_cfg("gru", "ternary")
    qvar = _packed_vars(cfg)
    packed_tb = BL.rnn_decode_tables(qvar, cfg, dense=False)
    dense_tb = BL.rnn_decode_tables(qvar, cfg, dense=True)
    st = _walked_state(qvar, cfg, packed_tb, 2)
    tok = jnp.array([4, 8], jnp.int32)
    lg_k, st_k = BL.rnn_decode_step(qvar, tok, cfg, st, tables=packed_tb,
                                    fused=True, interpret=True)
    lg_p, st_p = BL.rnn_decode_step(qvar, tok, cfg, st, tables=packed_tb,
                                    fused=False)
    lg_d, st_d = BL.rnn_decode_step(qvar, tok, cfg, st, tables=dense_tb,
                                    fused=False)
    np.testing.assert_allclose(np.asarray(lg_k), np.asarray(lg_p), atol=1e-5)
    np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg_d), atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_k.h), np.asarray(st_d.h),
                               atol=1e-5)
