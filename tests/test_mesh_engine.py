"""Mesh-parallel ServeEngine (DESIGN.md §12).

The engine's mesh mode shards the slot pool over the mesh's data axes and
the weights tensor-parallel over 'model', with every jitted region's in/out
shardings pinned.  The contract is the §7 one, extended across devices:
sharding is INVISIBLE — a data-sharded engine must produce BYTE-identical
per-request streams to the single-device engine on the same workload (which
tier-1 already proves byte-identical to the sequential oracle), the tick
must still trace exactly once for the engine's life, and the data-parallel
hot path must compile to ZERO collective ops (proven on the tick's compiled
HLO via `dispatch.collective_ops`, plus `jax.debug` sharding inspection of
the live pool).

Workloads are the existing fuzz harness's seeded scenarios (a subset of the
21 seeds across the LSTM-packed and qwen3 families) with the slot count
overridden to divide the data axis — scenario slots of 1-3 can't shard
4-way, and slot count is schedule, not bytes (§7 per-request determinism).

Multi-device CPU needs XLA_FLAGS=--xla_force_host_platform_device_count=8
set BEFORE jax initializes (the dryrun.py pattern), which the tier-1
process can't do retroactively — so under a single device this file
re-runs ITSELF in a subprocess with the flag exported, and the real tests
run there (CI's tier-2 step exports the flag and runs them directly).
"""
import os
import subprocess
import sys

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_FORCED = "xla_force_host_platform_device_count" in os.environ.get(
    "XLA_FLAGS", "")


def test_make_host_mesh_rejects_nondivisor():
    """The old silent gcd-shrink is gone: a model axis that does not divide
    the device count raises and names the shape the fallback would have
    built (runs at ANY device count — 3 divides neither 1 nor 8)."""
    import jax
    from repro.launch.mesh import make_host_mesh

    n = len(jax.devices())
    assert n % 3, "test assumes a device count 3 does not divide"
    with pytest.raises(ValueError, match=r"does not divide"):
        make_host_mesh(model=3)
    with pytest.raises(ValueError, match=r"model=1"):
        # the message must NAME the resolved fallback shape (gcd(3, n)=1)
        make_host_mesh(model=3)
    mesh = make_host_mesh(model=1)
    assert mesh.shape == {"data": n}


def test_parse_mesh_spec():
    from repro.launch.mesh import parse_mesh_spec

    assert parse_mesh_spec("data=4,model=2") == {"data": 4, "model": 2}
    assert parse_mesh_spec("model=2") == {"data": 1, "model": 2}
    assert parse_mesh_spec("") == {"data": 1, "model": 1}
    with pytest.raises(ValueError, match="unknown mesh axis"):
        parse_mesh_spec("pod=2")
    with pytest.raises(ValueError, match="bad mesh spec"):
        parse_mesh_spec("data:4")


if not _FORCED:

    def test_mesh_suite_under_forced_devices():
        """Re-run this file under 8 forced host devices so plain tier-1
        proves mesh parity too (the flag must be set before jax's first
        backend init — impossible in-process here)."""
        env = dict(os.environ,
                   XLA_FLAGS="--xla_force_host_platform_device_count=8",
                   JAX_PLATFORMS="cpu")
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(_REPO, "src"), env.get("PYTHONPATH", "")])
        r = subprocess.run(
            [sys.executable, "-m", "pytest", "-q", os.path.abspath(__file__)],
            env=env, cwd=_REPO, capture_output=True, text=True)
        assert r.returncode == 0, (
            "mesh suite failed under forced devices:\n"
            + r.stdout[-6000:] + r.stderr[-2000:])

else:
    import dataclasses
    import random

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.core import bnlstm as BL
    from repro.core.qtensor import export_packed, is_qtensor
    from repro.core.quantize import QuantSpec
    from repro.kernels import dispatch
    from repro.launch.mesh import make_host_mesh, make_serve_mesh
    from repro.launch.sharding import serve_pool_shardings
    from repro.models import transformer as T
    from repro.serve.engine import Request, ServeEngine
    from repro.serve.recurrent import (RNNRuntime, TransformerRuntime,
                                       speculative_draft)

    pytestmark = pytest.mark.skipif(len(jax.devices()) < 8,
                                    reason="needs 8 forced host devices")

    CTX = 32
    SLOTS = 8
    CHUNK = 4
    _RUNTIMES: dict = {}
    _ENGINES: dict = {}

    def _runtime(family):
        if family not in _RUNTIMES:
            if family == "lstm-packed":
                cfg = BL.RNNConfig(vocab=24, d_hidden=48, n_layers=2,
                                   cell="lstm",
                                   quant=QuantSpec(mode="ternary",
                                                   norm="batch"))
                var = BL.rnn_lm_init(jax.random.PRNGKey(0), cfg)
                params = BL.export_packed_rnn(var["params"], cfg)
                rt = RNNRuntime(cfg, {"params": params,
                                      "state": var["state"]})
                _RUNTIMES[family] = (rt, cfg.vocab)
            elif family == "qwen3":
                cfg = get_config("qwen3-0.6b").reduced()
                rt = TransformerRuntime(
                    cfg, T.model_init(jax.random.PRNGKey(0), cfg))
                _RUNTIMES[family] = (rt, cfg.vocab)
            else:  # qwen3-packed: QTensor codes through serve shardings
                cfg = get_config("qwen3-0.6b").reduced().with_quant(
                    QuantSpec(mode="ternary", norm="channel"))
                params = export_packed(
                    T.model_init(jax.random.PRNGKey(0), cfg), cfg.quant)
                rt = TransformerRuntime(cfg, params)
                _RUNTIMES[family] = (rt, cfg.vocab)
        return _RUNTIMES[family]

    def _engine(family, mesh_spec):
        """Engines cached per (family, mesh) and reused across scenarios —
        the compile-once invariant is re-proven under workload churn, the
        same discipline as the fuzz harness."""
        key = (family, mesh_spec)
        if key not in _ENGINES:
            rt, vocab = _runtime(family)
            mesh = make_serve_mesh(mesh_spec) if mesh_spec else None
            _ENGINES[key] = ServeEngine(rt, vocab, slots=SLOTS,
                                        max_context=CTX,
                                        prefill_chunk=CHUNK, mesh=mesh)
        return _ENGINES[key]

    def _scenario_requests(seed, vocab):
        """The fuzz harness's request mix for a seed (same generator as
        tests/test_engine_fuzz._scenario; slots/chunk/eos draws are kept so
        the request stream matches that seed byte-for-byte, then ignored —
        mesh engines need slots divisible by the data axis)."""
        import test_engine_fuzz as fuzz

        reqs, _eos, _slots, _chunk = fuzz._scenario(seed, vocab)
        return reqs

    def _drain(eng, reqs):
        comps, _ = eng.run([dataclasses.replace(r) for r in reqs],
                           realtime=False)
        assert eng.tick_traces == 1
        return {c.rid: (c.tokens, c.finished) for c in comps}

    # -- BYTE parity: data-sharded == single-device --------------------------

    @pytest.mark.parametrize("seed", [100, 101, 103])
    def test_lstm_packed_data4_byte_parity(seed):
        rt, vocab = _runtime("lstm-packed")
        reqs = _scenario_requests(seed, vocab)
        assert _drain(_engine("lstm-packed", "data=4"), reqs) == \
            _drain(_engine("lstm-packed", ""), reqs)

    def test_lstm_packed_data8_byte_parity():
        rt, vocab = _runtime("lstm-packed")
        reqs = _scenario_requests(104, vocab)
        assert _drain(_engine("lstm-packed", "data=8"), reqs) == \
            _drain(_engine("lstm-packed", ""), reqs)

    @pytest.mark.parametrize("seed", [300, 302])
    def test_qwen3_data4_byte_parity(seed):
        rt, vocab = _runtime("qwen3")
        reqs = _scenario_requests(seed, vocab)
        assert _drain(_engine("qwen3", "data=4"), reqs) == \
            _drain(_engine("qwen3", ""), reqs)

    def test_spec_engine_data4_byte_parity():
        """Draft-verify-accept slot surgery is shard-aware too: a
        speculative mesh engine's streams match the single-device one."""
        cfg = BL.RNNConfig(vocab=24, d_hidden=48, n_layers=2, cell="lstm",
                           quant=QuantSpec(mode="none"))
        var = BL.rnn_lm_init(jax.random.PRNGKey(0), cfg)
        rt = RNNRuntime(cfg, {"params": var["params"],
                              "state": var["state"]})
        draft = speculative_draft(rt)
        reqs = [dataclasses.replace(r, temperature=0.0, top_k=0)
                for r in _scenario_requests(105, cfg.vocab)]
        streams = []
        for spec in ("", "data=4"):
            mesh = make_serve_mesh(spec) if spec else None
            eng = ServeEngine(rt, cfg.vocab, slots=SLOTS, max_context=CTX,
                              prefill_chunk=CHUNK, draft=draft, spec_k=3,
                              mesh=mesh)
            comps, _ = eng.run([dataclasses.replace(r) for r in reqs],
                               realtime=False)
            assert eng.spec_traces == 1
            streams.append({c.rid: c.tokens for c in comps})
        assert streams[0] == streams[1]

    # -- no resharding on the hot path ---------------------------------------

    @pytest.mark.parametrize("family", ["lstm-packed", "qwen3"])
    def test_data_sharded_tick_is_collective_free(family):
        """The data-parallel decode tick compiles to ZERO collective ops:
        rows are independent, weights are replicated, and the per-slot
        cache scatters are vmapped (index-parallel) — so N slot shards
        never add wire traffic.  (Tensor-parallel ticks legitimately
        reduce over 'model' and are not asserted here.)"""
        eng = _engine(family, "data=4")
        assert dispatch.collective_ops(eng.tick_hlo()) == []
        assert eng.tick_traces == 1  # tick_hlo restores the counters

    def test_pool_shardings_are_the_declared_ones():
        """The live pool's committed shardings match serve_pool_shardings
        (out-sharding pinning worked — nothing decayed to replicated), and
        jax.debug's sharding inspection sees the data axis on every
        slot-bearing leaf from INSIDE a jitted computation."""
        eng = _engine("lstm-packed", "data=4")
        expect = serve_pool_shardings(eng.pool, eng._ref, eng.mesh)
        for leaf, sh in zip(jax.tree_util.tree_leaves(eng.pool),
                            jax.tree_util.tree_leaves(
                                expect, is_leaf=lambda x: hasattr(x, "spec"))):
            assert leaf.sharding.is_equivalent_to(sh, leaf.ndim), (
                f"{leaf.sharding} != declared {sh}")

        seen = []

        def probe(pool):
            for leaf in jax.tree_util.tree_leaves(pool):
                jax.debug.inspect_array_sharding(leaf, callback=seen.append)
            return pool

        jax.jit(probe)(eng.pool)
        leaves = jax.tree_util.tree_leaves(eng.pool)
        declared = jax.tree_util.tree_leaves(
            expect, is_leaf=lambda x: hasattr(x, "spec"))
        assert len(seen) == len(leaves)
        for got, leaf, sh in zip(seen, leaves, declared):
            # the compiler may report a PositionalSharding; equivalence to
            # the declared NamedSharding is the assertion that matters
            assert got.is_equivalent_to(sh, leaf.ndim), (got, sh)

    # -- tensor parallelism (packed codes over 'model') ----------------------

    def test_qwen3_packed_tp_serves_with_sharded_codes():
        """data=2 x model=2 over a PACKED qwen3: the engine drains a fuzz
        workload with QTensor codes genuinely sharded along 'model' (column
        axis for up-projections, packed-row axis for down-projections).
        TP reorders the contraction's partial sums, so this is a liveness +
        layout proof, not a byte assert (that's the DP tests' job)."""
        rt, vocab = _runtime("qwen3-packed")
        eng = ServeEngine(rt, vocab, slots=SLOTS, max_context=CTX,
                          prefill_chunk=CHUNK,
                          mesh=make_serve_mesh("data=2,model=2"))
        comps, _ = eng.run(_scenario_requests(301, vocab), realtime=False)
        assert comps and eng.tick_traces == 1
        qleaves = [l for l in jax.tree_util.tree_leaves(
            eng._prm, is_leaf=is_qtensor) if is_qtensor(l)]
        assert qleaves
        specs = [str(q.codes.sharding.spec) for q in qleaves]
        assert any("model" in s for s in specs), specs

    # -- mesh construction + shard bookkeeping -------------------------------

    def test_make_host_mesh_on_eight_devices():
        assert make_host_mesh(model=2).shape == {"data": 4, "model": 2}
        with pytest.raises(ValueError, match=r"data=8,model=1"):
            make_host_mesh(model=3)

    def test_slots_must_divide_data_shards():
        rt, vocab = _runtime("lstm-packed")
        with pytest.raises(ValueError, match="split evenly"):
            ServeEngine(rt, vocab, slots=6, max_context=CTX,
                        prefill_chunk=CHUNK, mesh=make_serve_mesh("data=4"))

    def test_stats_report_per_shard_occupancy():
        eng = _engine("lstm-packed", "data=4")
        rt, vocab = _runtime("lstm-packed")
        rng = random.Random(7)
        for i in range(3):  # leave requests IN FLIGHT, then look
            eng.submit(Request(prompt=np.array([rng.randrange(vocab)],
                                               np.int32),
                               max_tokens=4, temperature=0.0, top_k=0,
                               seed=i))
        for _ in range(2):
            eng.step()
        s = eng.stats()
        assert s["mesh"] == {"data": 4, "model": 1}
        assert len(s["shards"]) == 4
        assert sum(sh["active"] for sh in s["shards"]) == s["active"] == 3
        # the shard-aware free-slot balancer spread 3 admissions over 3
        # different shards instead of piling onto shard 0
        assert sum(sh["active"] > 0 for sh in s["shards"]) == 3
        assert s["queue_depth"] == s["queued"]
        while eng.has_work():
            eng.step()
