"""Scheduler parity fuzz harness (DESIGN.md §8).

Seeded-random mixed-traffic workloads — prompt lengths, arrival order,
max_tokens, per-request temperature / top-k, slot-pool size, prefill chunk
size, and eos ids chosen to collide with real streams — driven through the
continuous-batching `ServeEngine` and checked token-for-token against the
one-at-a-time sequential `drive_session` loop.  The engine's contract is
that scheduling is INVISIBLE: chunked in-slot prefill, slot assignment,
batch composition and admission order change the wall clock, never a byte
of any stream.

Scenarios are generated with plain `random.Random(seed)` parametrization
(hypothesis is not installable in this environment); each seed is one
deterministic scenario.  Engines are CACHED per (family, slots, chunk) and
reused across scenarios, so the suite also continuously re-proves the
compile-once invariant: `tick_traces == 1` for an engine's whole life, no
matter how many workloads it has drained.

Families covered: the paper's BN-LSTM full-precision and packed-ternary
(fused Pallas decode kernel), and a transformer-pool attention arch
(qwen3-0.6b) — 21 scenarios total.

The speculative half (DESIGN.md §9) rides the same harness: seeded
mixed-traffic scenarios at temperature 0 through a SPECULATIVE engine
(packed-ternary draft proposing for an fp target) must be byte-identical to
both the plain engine and the `drive_session` oracle — draft quality,
acceptance churn, per-round token counts and rollbacks change the schedule,
never a byte.  Spec engines are cached per (family, slots, chunk, k) and
assert `spec_traces == 1` for their whole life.
"""
import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import bnlstm as BL
from repro.core.quantize import QuantSpec
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine
from repro.serve.recurrent import (RNNRuntime, TransformerRuntime,
                                   drive_session, speculative_draft)

# small vocab on purpose: randomly drawn eos ids actually collide with
# sampled streams, so eos-mid-stream and eos-on-the-admission-token paths
# are exercised by the fuzz rather than hand-built
CTX = 32

_RUNTIMES: dict = {}
_ENGINES: dict = {}


def _runtime(family):
    """Build (and cache) one runtime per family — jitted prefill/decode
    compilations amortize across all scenarios of that family."""
    if family not in _RUNTIMES:
        if family.startswith("lstm"):
            packed = family == "lstm-packed"
            spec = (QuantSpec(mode="ternary", norm="batch") if packed
                    else QuantSpec(mode="none"))
            cfg = BL.RNNConfig(vocab=24, d_hidden=48, n_layers=2,
                               cell="lstm", quant=spec)
            var = BL.rnn_lm_init(jax.random.PRNGKey(0), cfg)
            params = var["params"]
            if packed:
                params = BL.export_packed_rnn(params, cfg)
            rt = RNNRuntime(cfg, {"params": params, "state": var["state"]})
            _RUNTIMES[family] = (rt, cfg.vocab, None)
        else:
            cfg = get_config("qwen3-0.6b").reduced()
            params = T.model_init(jax.random.PRNGKey(0), cfg)
            rt = TransformerRuntime(cfg, params)
            # the sequential baseline must attend over an identically
            # provisioned (masked) cache, so it gets the engine's context
            _RUNTIMES[family] = (rt, cfg.vocab, CTX)
    return _RUNTIMES[family]


def _engine(family, slots, chunk):
    key = (family, slots, chunk)
    if key not in _ENGINES:
        rt, vocab, _ = _runtime(family)
        _ENGINES[key] = ServeEngine(rt, vocab, slots=slots, max_context=CTX,
                                    prefill_chunk=chunk)
    return _ENGINES[key]


def _scenario(seed, vocab):
    """One deterministic mixed-traffic scenario from a seed."""
    rng = random.Random(seed)
    n = rng.randint(3, 6)
    reqs = [
        Request(
            prompt=np.array([rng.randrange(vocab)
                             for _ in range(rng.randint(1, 12))], np.int32),
            max_tokens=rng.randint(1, 8),
            temperature=rng.choice([0.0, 0.5, 0.8, 1.3]),
            top_k=rng.choice([0, 3, 7]),
            seed=rng.randrange(10_000),
            # realtime=False treats arrivals as admission priority only —
            # shuffling them permutes slot assignment scenario to scenario
            arrival_s=round(rng.random() * 0.05, 4),
            rid=i)
        for i in range(n)
    ]
    eos = rng.randrange(vocab) if rng.random() < 0.5 else None
    slots = rng.choice([1, 2, 3])
    chunk = rng.choice([2, 4])
    return reqs, eos, slots, chunk


def _expected(rt, vocab, ctx, req, eos):
    """The sequential oracle: the request alone through drive_session,
    truncated at the first eos (the engine retires there)."""
    out, _ = drive_session(
        rt, jnp.asarray(req.prompt)[None], vocab, gen=req.max_tokens,
        temperature=req.temperature, top_k=req.top_k, seed=req.seed,
        context=ctx)
    exp = out[0].tolist()
    if eos is not None and eos in exp:
        exp = exp[: exp.index(eos) + 1]
    return exp


FAMILY_SEEDS = (
    [("lstm-packed", s) for s in range(100, 108)]   # 8 scenarios
    + [("lstm-fp", s) for s in range(200, 207)]     # 7 scenarios
    + [("qwen3", s) for s in range(300, 306)]       # 6 scenarios
)                                                   # = 21 total


@pytest.mark.parametrize("family,seed", FAMILY_SEEDS,
                         ids=[f"{f}-{s}" for f, s in FAMILY_SEEDS])
def test_engine_fuzz_parity(family, seed):
    rt, vocab, ctx = _runtime(family)
    reqs, eos, slots, chunk = _scenario(seed, vocab)
    eng = _engine(family, slots, chunk)
    eng.eos_id = eos  # python-side retirement check: safe to vary per run

    comps, m = eng.run([dataclasses.replace(r) for r in reqs],
                       realtime=False)

    # compile-once + no-head-of-line-blocking invariants, across the
    # engine's whole life (engines are shared between scenarios)
    assert m["tick_traces"] == 1
    assert m["max_decode_stall_ticks"] <= 1

    by_rid = {c.rid: c for c in comps}
    assert sorted(by_rid) == [r.rid for r in sorted(reqs, key=lambda r: r.rid)]
    for r in reqs:
        c = by_rid[r.rid]
        assert c.tokens == _expected(rt, vocab, ctx, r, eos), \
            f"stream diverged for rid={r.rid} (seed={seed})"
        if eos is not None and c.tokens[-1] == eos:
            assert c.finished == "eos"
        else:
            assert len(c.tokens) == r.max_tokens
        assert c.t_admit <= c.t_first <= c.t_done

    # the engine is drained: every slot is reusable
    assert not eng._live_host.any() and not eng._prefill_q


# --- speculative decoding: same bar, plus the plain engine as a second oracle


_DRAFTS: dict = {}
_SPEC_ENGINES: dict = {}


def _draft(family):
    """Packed-ternary draft of the family's fp target, built once."""
    if family not in _DRAFTS:
        rt, _, _ = _runtime(family)
        _DRAFTS[family] = speculative_draft(rt, mode="ternary")
    return _DRAFTS[family]


def _spec_engine(family, slots, chunk, k):
    key = (family, slots, chunk, k)
    if key not in _SPEC_ENGINES:
        rt, vocab, _ = _runtime(family)
        _SPEC_ENGINES[key] = ServeEngine(
            rt, vocab, slots=slots, max_context=CTX, prefill_chunk=chunk,
            draft=_draft(family), spec_k=k)
    return _SPEC_ENGINES[key]


def _spec_scenario(seed, vocab):
    """Mixed-traffic scenario at temperature 0 — the byte-parity regime.
    (At temperature > 0 speculative output matches the target in
    DISTRIBUTION, which tests/test_spec_decode.py frequency-tests; byte
    equality is only defined for greedy streams.)"""
    reqs, eos, slots, chunk = _scenario(seed, vocab)
    reqs = [dataclasses.replace(r, temperature=0.0, top_k=0) for r in reqs]
    rng = random.Random(seed + 1)
    return reqs, eos, slots, chunk, rng.choice([2, 3])


SPEC_FAMILY_SEEDS = (
    [("lstm-fp", s) for s in range(400, 405)]       # 5 scenarios
    + [("qwen3", s) for s in range(500, 503)]       # 3 scenarios
)                                                   # = 8 total


@pytest.mark.parametrize("family,seed", SPEC_FAMILY_SEEDS,
                         ids=[f"spec-{f}-{s}" for f, s in SPEC_FAMILY_SEEDS])
def test_engine_spec_fuzz_parity(family, seed):
    rt, vocab, ctx = _runtime(family)
    reqs, eos, slots, chunk, k = _spec_scenario(seed, vocab)
    plain = _engine(family, slots, chunk)
    spec = _spec_engine(family, slots, chunk, k)
    plain.eos_id = spec.eos_id = eos

    p_comps, pm = plain.run([dataclasses.replace(r) for r in reqs],
                            realtime=False)
    s_comps, sm = spec.run([dataclasses.replace(r) for r in reqs],
                           realtime=False)

    # compile-once invariants, lifelong, for BOTH engines
    assert pm["tick_traces"] == 1
    assert sm["spec_traces"] == 1
    assert sm["max_decode_stall_ticks"] <= 1
    assert 0.0 <= sm["accept_rate"] <= 1.0

    p_by = {c.rid: c.tokens for c in p_comps}
    s_by = {c.rid: c for c in s_comps}
    assert sorted(s_by) == sorted(p_by)
    for r in reqs:
        c = s_by[r.rid]
        # byte parity against the plain engine AND the sequential oracle
        assert c.tokens == p_by[r.rid], \
            f"spec diverged from plain engine for rid={r.rid} (seed={seed})"
        assert c.tokens == _expected(rt, vocab, ctx, r, eos), \
            f"spec diverged from oracle for rid={r.rid} (seed={seed})"
        if eos is not None and c.tokens[-1] == eos:
            assert c.finished == "eos"
        else:
            assert len(c.tokens) == r.max_tokens
        assert c.t_admit <= c.t_first <= c.t_done

    assert not spec._live_host.any() and not spec._prefill_q
