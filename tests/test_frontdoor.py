"""Front door + resumable step API (DESIGN.md §10).

The engine grew `submit()`/`step()`/`cancel(rid)` so an event loop can
drive ticks while requests arrive and die asynchronously.  The contract
stays the PR 3 one: scheduling is INVISIBLE.  Driving the scheduler one
step at a time, over HTTP, with clients hanging up mid-stream, must leave
every SURVIVING stream byte-identical to the sequential `drive_session`
oracle — and must never trace a new tick (cancellation reuses the compiled
scrub; `tick_traces`/`spec_traces` stay 1 for the engine's life).

Engines are cached per (family, slots, chunk) and reused across tests, so
the suite re-proves the compile-once invariant under submit/cancel churn,
not just under batch replay.
"""
import asyncio
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import bnlstm as BL
from repro.core.quantize import QuantSpec
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine
from repro.serve.frontdoor import FrontDoor, _get_json, _post_stream
from repro.serve.recurrent import (RNNRuntime, TransformerRuntime,
                                   drive_session, speculative_draft)

CTX = 48

_RUNTIMES: dict = {}
_ENGINES: dict = {}


def _runtime(family):
    if family not in _RUNTIMES:
        if family.startswith("lstm"):
            packed = family == "lstm-packed"
            spec = (QuantSpec(mode="ternary", norm="batch") if packed
                    else QuantSpec(mode="none"))
            cfg = BL.RNNConfig(vocab=24, d_hidden=48, n_layers=2,
                               cell="lstm", quant=spec)
            var = BL.rnn_lm_init(jax.random.PRNGKey(0), cfg)
            params = var["params"]
            if packed:
                params = BL.export_packed_rnn(params, cfg)
            rt = RNNRuntime(cfg, {"params": params, "state": var["state"]})
            _RUNTIMES[family] = (rt, cfg.vocab, None)
        else:
            cfg = get_config("qwen3-0.6b").reduced()
            params = T.model_init(jax.random.PRNGKey(0), cfg)
            rt = TransformerRuntime(cfg, params)
            _RUNTIMES[family] = (rt, cfg.vocab, CTX)
    return _RUNTIMES[family]


def _engine(family, slots, chunk):
    key = (family, slots, chunk)
    if key not in _ENGINES:
        rt, vocab, _ = _runtime(family)
        _ENGINES[key] = ServeEngine(rt, vocab, slots=slots, max_context=CTX,
                                    prefill_chunk=chunk)
    return _ENGINES[key]


def _expected(family, req):
    rt, vocab, ctx = _runtime(family)
    out, _ = drive_session(
        rt, jnp.asarray(req.prompt)[None], vocab, gen=req.max_tokens,
        temperature=req.temperature, top_k=req.top_k, seed=req.seed,
        context=ctx)
    return out[0].tolist()


def _reqs(vocab, n, *, seed=0, max_prompt=12, max_gen=10):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, vocab,
                                        size=int(rng.integers(2, max_prompt))),
                    max_tokens=int(rng.integers(2, max_gen)),
                    temperature=0.8, top_k=5, seed=500 + i)
            for i in range(n)]


def _drain(eng):
    """Drive step() to empty, collecting per-rid streams and completions."""
    streams: dict = {}
    comps = []
    while eng.has_work():
        events, cs = eng.step()
        for rid, toks in events:
            streams.setdefault(rid, []).extend(toks)
        comps.extend(cs)
    return streams, comps


# --- the resumable step API --------------------------------------------------


@pytest.mark.parametrize("family", ["lstm-packed", "lstm-fp", "qwen3"])
def test_step_api_streams_match_drive_session(family):
    """submit-all + step-to-empty produces the exact per-request streams the
    batch run() (and therefore the sequential oracle) produces."""
    rt, vocab, _ = _runtime(family)
    eng = _engine(family, 2, 4)
    reqs = _reqs(vocab, 4, seed=11)
    rids = [eng.submit(dataclasses.replace(r)) for r in reqs]
    streams, comps = _drain(eng)
    assert sorted(streams) == sorted(rids) and len(comps) == len(reqs)
    for rid, req in zip(rids, reqs):
        assert streams[rid] == _expected(family, req), \
            f"step-API stream for rid {rid} diverged from the oracle"
    for c in comps:
        assert c.tokens == streams[c.rid]  # events and completions agree
    assert eng.tick_traces == 1


def test_run_is_the_step_loop():
    """The batch driver is a THIN wrapper: same engine, same streams."""
    rt, vocab, _ = _runtime("lstm-packed")
    eng = _engine("lstm-packed", 2, 4)
    reqs = _reqs(vocab, 5, seed=23)
    comps, m = eng.run([dataclasses.replace(r, rid=100 + i)
                        for i, r in enumerate(reqs)], realtime=False)
    by_rid = {c.rid: c.tokens for c in comps}
    for i, req in enumerate(reqs):
        assert by_rid[100 + i] == _expected("lstm-packed", req)
    assert m["tick_traces"] == 1 and eng.tick_traces == 1


# --- cancellation ------------------------------------------------------------


def test_cancel_mid_prefill():
    """Cancelling a request whose prompt is still chunk-prefilling frees the
    slot through the shape-aware scrub: the survivor's stream is untouched
    and the next occupant of that slot starts from a clean row."""
    rt, vocab, _ = _runtime("lstm-packed")
    eng = _engine("lstm-packed", 2, 2)
    long = Request(prompt=np.arange(12) % vocab, max_tokens=30,
                   temperature=0.0, seed=1)       # 6 chunks of 2
    short = _reqs(vocab, 1, seed=31)[0]
    rid_l = eng.submit(dataclasses.replace(long))
    rid_s = eng.submit(dataclasses.replace(short))
    eng.step()  # admits both, runs ONE chunk of the long prompt
    assert eng._active[0] is not None and eng._active[0].chunks
    traces = (eng.tick_traces, eng.prefill_traces)
    comp = eng.cancel(rid_l)
    assert (eng.tick_traces, eng.prefill_traces) == traces, \
        "cancellation must not trace anything new"
    assert comp.finished == "cancelled" and comp.tokens == []
    assert eng._active[0] is None and 0 not in eng._prefill_q
    streams, comps = _drain(eng)
    assert [c.rid for c in comps] == [rid_s]
    assert streams[rid_s] == _expected("lstm-packed", short)
    # the freed slot is immediately reusable and reads like fresh
    readmit = Request(prompt=np.asarray(long.prompt), max_tokens=6,
                      temperature=0.0, seed=1)
    rid2 = eng.submit(dataclasses.replace(readmit))
    streams2, comps2 = _drain(eng)
    assert comps2[0].slot in (0, 1)
    assert streams2[rid2] == _expected("lstm-packed", readmit)
    assert eng.tick_traces == 1


def test_cancel_queued_request_never_touches_a_slot():
    rt, vocab, _ = _runtime("lstm-packed")
    eng = _engine("lstm-packed", 1, 4)
    a, b = _reqs(vocab, 2, seed=41)
    rid_a = eng.submit(dataclasses.replace(a))
    rid_b = eng.submit(dataclasses.replace(b))   # queued: one slot
    eng.step()
    comp = eng.cancel(rid_b)
    assert comp is not None and comp.finished == "cancelled"
    assert comp.slot == -1 and comp.tokens == []
    streams, comps = _drain(eng)
    assert [c.rid for c in comps] == [rid_a]
    assert streams[rid_a] == _expected("lstm-packed", a)
    assert eng.cancel(rid_b) is None  # already gone: idempotent


def test_disconnect_then_readmit_same_slot():
    """The front-door disconnect path: cancel a DECODING request, then the
    next request lands in the same slot and must stream exactly the oracle
    — nothing of the dead request leaks through the scrub."""
    rt, vocab, _ = _runtime("lstm-fp")
    eng = _engine("lstm-fp", 1, 4)
    a = Request(prompt=np.arange(5) % vocab, max_tokens=30, temperature=0.8,
                top_k=5, seed=7)
    b = _reqs(vocab, 1, seed=51)[0]
    rid_a = eng.submit(dataclasses.replace(a))
    got_a = []
    for _ in range(6):  # prefill (2 chunks) + a few decode ticks
        events, _ = eng.step()
        for rid, toks in events:
            got_a.extend(toks)
    assert eng._live_host[0] and len(got_a) >= 2
    comp_a = eng.cancel(rid_a)
    assert comp_a.finished == "cancelled" and comp_a.tokens == got_a
    assert comp_a.tokens == _expected("lstm-fp", a)[:len(got_a)], \
        "the partial stream up to the hangup is still oracle-exact"
    rid_b = eng.submit(dataclasses.replace(b))
    streams, comps = _drain(eng)
    assert comps[0].rid == rid_b and comps[0].slot == 0  # SAME slot
    assert streams[rid_b] == _expected("lstm-fp", b)
    assert eng.tick_traces == 1


def test_cancel_between_spec_rounds():
    """Speculative engines cancel at the only boundary that exists — between
    one draft-verify-accept round and the next.  Killing a slot mid-flight
    must leave the survivors' streams byte-identical to the oracle (the
    draft pool's rollback state for the dead slot is scrubbed with it) and
    trace nothing new."""
    rt, vocab, _ = _runtime("lstm-fp")
    key = ("spec", 2, 4, 3)
    if key not in _ENGINES:
        _ENGINES[key] = ServeEngine(
            rt, vocab, slots=2, max_context=CTX, prefill_chunk=4,
            draft=speculative_draft(rt, mode="ternary"), spec_k=3)
    eng = _ENGINES[key]
    a = Request(prompt=np.arange(4) % vocab, max_tokens=24, temperature=0.0,
                seed=3)
    b = Request(prompt=(np.arange(6) * 5) % vocab, max_tokens=10,
                temperature=0.0, seed=4)
    rid_a = eng.submit(dataclasses.replace(a))
    rid_b = eng.submit(dataclasses.replace(b))
    got = {rid_a: [], rid_b: []}
    while not (eng._live_host[0] and eng._live_host[1]):
        for rid, toks in eng.step()[0]:
            got[rid].extend(toks)
    for rid, toks in eng.step()[0]:  # >= one spec round, both slots live
        got[rid].extend(toks)
    traces = eng.spec_traces
    comp_a = eng.cancel(rid_a)
    assert eng.spec_traces == traces, \
        "spec cancel churn must not retrace the round"
    assert comp_a.finished == "cancelled" and comp_a.tokens == got[rid_a]
    assert comp_a.tokens == _expected("lstm-fp", a)[:len(comp_a.tokens)]
    streams, comps = _drain(eng)
    assert [c.rid for c in comps] == [rid_b]
    assert got[rid_b] + streams.get(rid_b, []) == comps[0].tokens
    assert comps[0].tokens == _expected("lstm-fp", b)
    assert eng.spec_traces == 1


# --- priority / SLO admission ------------------------------------------------


def test_priority_orders_admission_not_preemption():
    rt, vocab, _ = _runtime("lstm-packed")
    eng = _engine("lstm-packed", 1, 4)
    reqs = [Request(prompt=np.arange(3) % vocab, max_tokens=3,
                    temperature=0.0, seed=60 + i, priority=p, slo=s)
            for i, (p, s) in enumerate([(5, "batch"), (0, "realtime"),
                                        (2, "standard")])]
    rids = [eng.submit(dataclasses.replace(r)) for r in reqs]
    streams, comps = _drain(eng)
    # one slot: completion order IS admission order -> priority order
    assert [c.rid for c in comps] == [rids[1], rids[2], rids[0]]
    assert [c.slo for c in comps] == ["realtime", "standard", "batch"]
    for rid, req in zip(rids, reqs):
        assert streams[rid] == _expected("lstm-packed", req), \
            "admission order must never change a stream's bytes"


def test_ttft_reported_per_slo_class():
    rt, vocab, _ = _runtime("lstm-packed")
    eng = _engine("lstm-packed", 2, 4)
    reqs = _reqs(vocab, 4, seed=71)
    reqs = [dataclasses.replace(r, slo="interactive" if i % 2 else "batch",
                                priority=0 if i % 2 else 1)
            for i, r in enumerate(reqs)]
    _, m = eng.run(reqs, realtime=False)
    cls = m["ttft_by_class"]
    assert set(cls) == {"interactive", "batch"}
    for v in cls.values():
        assert v["n"] == 2 and 0 <= v["p50_s"] <= v["p95_s"]


# --- the HTTP/SSE layer ------------------------------------------------------


def _sse_roundtrip(eng, payloads, hangup_after=None):
    """Serve `eng` on an ephemeral port, POST each payload, return the
    streamed tokens (+ done events).  `hangup_after` maps payload index ->
    close-after-N-events (the disconnect path)."""
    hangup_after = hangup_after or {}

    async def go():
        fd = FrontDoor(eng, port=0)
        await fd.start()
        try:
            outs = []
            for i, p in enumerate(payloads):
                outs.append(await _post_stream(fd.host, fd.port, p,
                                               hangup_after=hangup_after.get(i)))
                await asyncio.sleep(0.05)  # let a hangup cancel before next
            stats = await _get_json(fd.host, fd.port, "/v1/stats")
            return outs, stats
        finally:
            await fd.close()

    return asyncio.run(go())


@pytest.mark.parametrize("family", ["lstm-packed", "lstm-fp", "qwen3"])
def test_sse_streams_are_oracle_exact(family):
    """The acceptance bar: token sequences streamed over HTTP/SSE are
    byte-identical to drive_session for the same seed/params, with the
    tick compiled exactly once under submit/cancel churn."""
    rt, vocab, _ = _runtime(family)
    eng = _engine(family, 2, 4)
    reqs = _reqs(vocab, 3, seed=83)
    payloads = [{"prompt": np.asarray(r.prompt).tolist(),
                 "max_tokens": r.max_tokens, "temperature": r.temperature,
                 "top_k": r.top_k, "seed": r.seed} for r in reqs]
    # payload 1 hangs up after its first token event (mid-stream cancel);
    # bump its gen budget so there IS a mid-stream to hang up in
    payloads[1]["max_tokens"] = 20
    outs, stats = _sse_roundtrip(eng, payloads, hangup_after={1: 1})
    for i in (0, 2):
        toks, done = outs[i]
        assert done is not None and done["finished"] in ("eos", "length")
        assert toks == _expected(family, reqs[i]), \
            f"SSE stream {i} diverged from the sequential oracle"
    # the cancelled stream's prefix is oracle-exact too
    cut, _ = outs[1]
    exp1 = _expected(family, dataclasses.replace(reqs[1], max_tokens=20))
    assert cut == exp1[:len(cut)]
    assert stats["active"] == 0 and stats["queued"] == 0
    assert stats["tick_traces"] == 1


def test_http_bad_requests_are_rejected():
    eng = _engine("lstm-packed", 2, 4)

    async def go():
        fd = FrontDoor(eng, port=0)
        await fd.start()
        try:
            r1, w1 = await asyncio.open_connection(fd.host, fd.port)
            body = b'{"prompt": [1, 2], "max_tokens": 0}'  # invalid budget
            w1.write(b"POST /v1/generate HTTP/1.1\r\nContent-Length: "
                     + str(len(body)).encode() + b"\r\n\r\n" + body)
            await w1.drain()
            resp = await r1.read()
            w1.close()
            nf = await _get_json(fd.host, fd.port, "/nope")
            return resp, nf
        finally:
            await fd.close()

    resp, nf = asyncio.run(go())
    assert b"400 Bad Request" in resp and b"max_tokens" in resp
    assert "error" in nf
    assert not eng.has_work()


def test_stats_report_per_shard_occupancy_and_queue_depth():
    """`stats()` / `/v1/stats` carry the mesh-serving observability fields
    on EVERY engine (DESIGN.md §12): a `shards` list (one row per data
    shard; a single-device engine is one shard spanning all slots) whose
    `active` sums to the engine's, plus `queue_depth` for the admission
    queue — so dashboards need no schema fork when --mesh lands."""
    rt, vocab, _ = _runtime("lstm-packed")
    eng = _engine("lstm-packed", 2, 4)
    for r in _reqs(vocab, 3, seed=91):   # 3 requests, 2 slots -> 1 queued
        eng.submit(r)
    eng.step()
    mid = eng.stats()
    assert mid["queue_depth"] == mid["queued"] == 1
    assert [s["shard"] for s in mid["shards"]] == [0]
    assert mid["shards"][0]["slots"] == 2
    assert sum(s["active"] for s in mid["shards"]) == mid["active"] == 2
    assert mid["shards"][0]["occupancy"] == 1.0
    assert "mesh" not in mid             # meshless engine: no mesh block
    _drain(eng)

    stats = _sse_roundtrip(eng, [])[1]   # same fields over HTTP
    assert stats["queue_depth"] == 0
    assert stats["shards"][0]["active"] == 0
    assert stats["shards"][0]["occupancy"] == 0.0
