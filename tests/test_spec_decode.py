"""Speculative decoding (DESIGN.md §9): draft-verify-accept must change the
schedule, never the distribution — and at temperature 0, never a byte.

Four layers of proof:
  * sampler properties — the Leviathan identity q(v)·min(1, p(v)/q(v)) +
    P(reject)·residual(v) == p(v) holds for random (p, q) pairs.  Run as a
    seeded `random.Random` property loop (hypothesis is not installable in
    this environment, so a @given here would silently skip — the loop keeps
    the property coverage in tier-1);
  * statistical acceptance — frequency-testing `spec_accept` on a tiny
    vocab shows the emitted-token marginal matches the target distribution,
    and forcing p_draft == p_target accepts every draft;
  * rollback bit-exactness — committing 0 tokens of a verify restores the
    full state tree (RNN h/c/pos, transformer KV BYTES + pos) bit-for-bit,
    and committing j tokens equals j plain decode steps bit-for-bit;
  * engine invariants — a spec engine whose draft IS its target accepts
    everything; unsupported runtimes (ring caches, hybrids) are refused.
"""
import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import bnlstm as BL
from repro.core.quantize import QuantSpec
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine
from repro.serve.kvcache import cache_spec_commit, cache_spec_snapshot
from repro.serve.recurrent import (RNNRuntime, TransformerRuntime,
                                   speculative_draft)
from repro.serve.sampler import (filtered_probs, residual_probs, sample_slots,
                                 spec_accept)


def _rnn_runtime(packed=False, seed=0):
    spec = (QuantSpec(mode="ternary", norm="batch") if packed
            else QuantSpec(mode="none"))
    cfg = BL.RNNConfig(vocab=24, d_hidden=48, n_layers=2, cell="lstm",
                       quant=spec)
    var = BL.rnn_lm_init(jax.random.PRNGKey(seed), cfg)
    params = var["params"]
    if packed:
        params = BL.export_packed_rnn(params, cfg)
    return cfg, RNNRuntime(cfg, {"params": params, "state": var["state"]})


# --- sampler properties: seeded random.Random loop (no hypothesis) -----------


def test_residual_identity_property_loop():
    """The rejection-sampling identity, the reason speculative output IS the
    target distribution: for every token v,
        q(v) * min(1, p(v)/q(v)) + (1 - sum_u q(u) min(1, p(u)/q(u))) * r(v)
    equals p(v), where r = residual_probs(p, q).  40 seeded random (p, q)
    pairs, including near-equal and disjoint-support shapes."""
    rng = random.Random(1234)
    for case in range(40):
        V = rng.randint(2, 12)
        logp = np.array([rng.gauss(0, 2) for _ in range(V)])
        if case % 4 == 0:      # near-identical distributions
            logq = logp + np.array([rng.gauss(0, 1e-3) for _ in range(V)])
        elif case % 4 == 1:    # near-disjoint support
            logq = np.roll(logp, 1) + np.array(
                [rng.gauss(0, 3) for _ in range(V)])
        else:
            logq = np.array([rng.gauss(0, 2) for _ in range(V)])
        p = np.exp(logp) / np.exp(logp).sum()
        q = np.exp(logq) / np.exp(logq).sum()
        r = np.asarray(residual_probs(jnp.asarray(p)[None],
                                      jnp.asarray(q)[None]))[0]
        acc = q * np.minimum(1.0, p / q)
        out = acc + (1.0 - acc.sum()) * r
        np.testing.assert_allclose(out, p, atol=1e-6,
                                   err_msg=f"identity failed (case {case})")
        assert r.min() >= 0 and abs(r.sum() - 1.0) < 1e-6


def test_residual_zero_mass_falls_back_to_target():
    p = jnp.array([[0.25, 0.75]])
    r = residual_probs(p, p)  # residual mass is exactly zero
    np.testing.assert_allclose(np.asarray(r), np.asarray(p))


def test_filtered_probs_matches_sample_slots_semantics():
    """filtered_probs is the distribution sample_slots draws from: one-hot
    at the greedy argmax for temperature <= 0, softmax of the SAME
    filtered/scaled logits otherwise (top-k zeroes everything below the
    k-th largest)."""
    logits = jax.random.normal(jax.random.PRNGKey(0), (4, 9))
    temps = jnp.array([0.0, 1.0, 0.7, 2.0])
    topks = jnp.array([0, 0, 3, 9], jnp.int32)
    P = filtered_probs(logits, temps, topks, vocab=7)
    P = np.asarray(P)
    # row 0: greedy one-hot at the vocab-masked argmax
    g = int(jnp.argmax(jnp.where(jnp.arange(9) < 7, logits[0], -jnp.inf)))
    assert P[0, g] == 1.0 and P[0].sum() == 1.0
    # vocab mask: padded ids carry zero mass in every row
    assert float(P[:, 7:].max()) == 0.0
    # row 2: top-3 keeps exactly 3 tokens with mass
    assert int((P[2] > 0).sum()) == 3
    np.testing.assert_allclose(P.sum(-1), 1.0, atol=1e-6)
    # stochastic rows: empirical sample_slots frequencies match
    N = 4000
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(N))
    row = jnp.broadcast_to(logits[2], (N, 9))
    draws = np.asarray(sample_slots(
        row, keys, temperature=jnp.full((N,), 0.7),
        top_k=jnp.full((N,), 3, jnp.int32), vocab=7))
    freq = np.bincount(draws, minlength=9) / N
    np.testing.assert_allclose(freq, P[2], atol=0.04)


# --- statistical acceptance ---------------------------------------------------


def _accept_batch(n, seed=0, *, equal=False, K=2, V=5):
    """spec_accept over n identical (p, q) slots with distinct keys: the
    per-slot vectorization doubles as a Monte Carlo harness."""
    kp, kq, kd = jax.random.split(jax.random.PRNGKey(seed), 3)
    p_logits = jnp.broadcast_to(jax.random.normal(kp, (K + 1, V)), (n, K + 1, V))
    q_row = p_logits[0, :K] if equal else jax.random.normal(kq, (K, V))
    q_logits = jnp.broadcast_to(q_row, (n, K, V))
    temp = jnp.ones((n,))
    topk = jnp.zeros((n,), jnp.int32)
    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(n))
    # drafts sampled from q per position, per slot — the spec tick's draft
    # loop with the state dependency cut (q is fixed per position here)
    dkeys = jax.vmap(lambda k: jax.random.split(k, K))(
        jax.vmap(jax.random.fold_in, (0, None))(keys, 7))
    drafts = jnp.stack(
        [sample_slots(q_logits[:, i], dkeys[:, i], temperature=temp,
                      top_k=topk, vocab=V) for i in range(K)], axis=1)
    n_acc, out = jax.jit(lambda *a: spec_accept(
        a[0], a[1], a[2], a[3], temperature=temp, top_k=topk, vocab=V))(
        p_logits, q_logits, drafts, keys)
    return np.asarray(p_logits[0]), np.asarray(n_acc), np.asarray(out)


def test_spec_accept_matches_target_distribution():
    """The first emitted token of every slot (draft-if-accepted else
    residual resample) must be distributed as the TARGET's position-0
    distribution — the output distribution is exactly p, never q."""
    p_logits, n_acc, out = _accept_batch(4000, seed=3)
    target = np.asarray(jax.nn.softmax(jnp.asarray(p_logits[0])))
    freq = np.bincount(out[:, 0], minlength=5) / len(out)
    np.testing.assert_allclose(freq, target, atol=0.04)
    assert n_acc.min() >= 1 and n_acc.max() <= 3


def test_spec_accept_equal_distributions_accept_everything():
    """p_draft == p_target: the ratio is 1 everywhere, every draft is
    accepted, and every slot emits the full K+1 (drafts + bonus)."""
    _, n_acc, _ = _accept_batch(500, seed=5, equal=True)
    assert (n_acc == 3).all()


def test_spec_accept_greedy_is_target_argmax():
    """temperature 0: whatever the drafts, the emitted prefix is exactly
    the target's greedy chain prefix."""
    V, K = 6, 3
    p_logits = jax.random.normal(jax.random.PRNGKey(2), (1, K + 1, V))
    greedy = np.asarray(jnp.argmax(p_logits[0], -1))
    for draft_case in range(5):
        drafts = jax.random.randint(jax.random.PRNGKey(draft_case),
                                    (1, K), 0, V)
        q_logits = jax.random.normal(jax.random.PRNGKey(draft_case + 10),
                                     (1, K, V))
        n_acc, out = spec_accept(
            p_logits, q_logits, drafts, jnp.asarray([[0, 1]], jnp.uint32),
            temperature=jnp.zeros((1,)), top_k=jnp.zeros((1,), jnp.int32),
            vocab=V)
        n = int(n_acc[0])
        assert np.asarray(out)[0, :n].tolist() == greedy[:n].tolist()


# --- verify: bit-parity with sequential decode --------------------------------


def test_rnn_verify_matches_sequential_decode_steps():
    cfg, rt = _rnn_runtime()
    B, K = 3, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, K), 0, cfg.vocab)
    st0 = BL.rnn_state_init(cfg, B, per_slot=True)
    _, st0 = rt.prefill(jax.random.randint(jax.random.PRNGKey(2), (B, 3),
                                           0, cfg.vocab), st0)
    # variables/tables as jit ARGS, matching rt.decode_step's compilation
    # (a closed-over tree constant-folds to ulp-different logits; the
    # engine closes over constants on BOTH sides of its parity bar, which
    # the fuzz harness proves at stream level)
    lgs, end, emits = jax.jit(
        lambda v, tb, tk, s: BL.rnn_verify(v, tk, cfg, s, tables=tb))(
        rt.variables, rt.tables, toks, st0)
    st = st0
    for i in range(K):
        lg, st = rt.decode_step(toks[:, i], st)
        np.testing.assert_array_equal(np.asarray(lgs[:, i]), np.asarray(lg))
    np.testing.assert_array_equal(np.asarray(end.h), np.asarray(st.h))
    np.testing.assert_array_equal(np.asarray(end.c), np.asarray(st.c))


def test_transformer_verify_matches_sequential_decode_steps():
    cfg = get_config("qwen3-0.6b").reduced()
    rt = TransformerRuntime(cfg, T.model_init(jax.random.PRNGKey(0), cfg))
    B, K = 2, 3
    st0 = rt.init_state(B, 24, per_slot=True)
    _, st0 = rt.prefill(jax.random.randint(jax.random.PRNGKey(2), (B, 4),
                                           0, cfg.vocab), st0)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, K), 0, cfg.vocab)
    lgs, end, _ = jax.jit(rt.verify)(toks, st0)
    st = st0
    for i in range(K):
        lg, st = rt.decode_step(toks[:, i], st)
        np.testing.assert_array_equal(np.asarray(lgs[:, i]), np.asarray(lg))
    for a, b in zip(jax.tree_util.tree_leaves(end),
                    jax.tree_util.tree_leaves(st)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --- rollback bit-exactness ---------------------------------------------------


def test_rnn_rollback_restores_snapshot_bit_exact():
    """Reject-everything (n = 0): the committed tree is the pre-verify
    snapshot, bit for bit — h, c AND pos."""
    cfg, rt = _rnn_runtime()
    B = 2
    st0 = BL.rnn_state_init(cfg, B, per_slot=True)
    _, st0 = rt.prefill(jax.random.randint(jax.random.PRNGKey(3), (B, 5),
                                           0, cfg.vocab), st0)
    snap = jax.tree.map(lambda a: np.asarray(a).copy(), st0)
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, 3), 0, cfg.vocab)
    _, end, emits = rt.verify(toks, st0)
    committed = rt.spec_commit(st0, end, (), emits, jnp.zeros((B,), jnp.int32))
    for a, b in zip(jax.tree_util.tree_leaves(committed),
                    jax.tree_util.tree_leaves(snap)):
        np.testing.assert_array_equal(np.asarray(a), b)


@pytest.mark.parametrize("n_commit", [0, 2])
def test_transformer_rollback_restores_kv_bytes_bit_exact(n_commit):
    """The KV rollback is byte surgery, not just pos masking: committing n
    of a verified span leaves the cache tree — bytes INCLUDED — bit-
    identical to a cache that plain-decoded exactly n of those tokens.
    n = 0 is the reject-at-position-0 case: the restored tree equals the
    pre-verify snapshot."""
    cfg = get_config("qwen3-0.6b").reduced()
    rt = TransformerRuntime(cfg, T.model_init(jax.random.PRNGKey(0), cfg))
    B, K = 2, 3
    st0 = rt.init_state(B, 24, per_slot=True)
    _, st0 = rt.prefill(jax.random.randint(jax.random.PRNGKey(2), (B, 4),
                                           0, cfg.vocab), st0)
    snap_tree = jax.tree.map(
        lambda a: np.asarray(a).copy(), st0,
        is_leaf=lambda x: hasattr(x, "dtype"))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, K), 0, cfg.vocab)

    snap = rt.spec_snapshot(st0, K)
    _, after, _ = rt.verify(toks, st0)
    n = jnp.full((B,), n_commit, jnp.int32)
    committed = rt.spec_commit(st0, after, snap, (), n)

    if n_commit == 0:
        ref = st0  # the pre-verify tree, bytes and all
    else:
        ref = st0
        for i in range(n_commit):
            _, ref = rt.decode_step(toks[:, i], ref)
    for a, b in zip(jax.tree_util.tree_leaves(committed),
                    jax.tree_util.tree_leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and the original snapshot materials were never aliased/mutated
    for a, b in zip(jax.tree_util.tree_leaves(st0),
                    jax.tree_util.tree_leaves(snap_tree)):
        np.testing.assert_array_equal(np.asarray(a), b)


def test_cache_spec_snapshot_commit_unit():
    """Bare-cache unit: per-slot span gather + suffix restore at mixed
    depths and mixed keep counts."""
    from repro.serve.kvcache import cache_init, cache_update
    c = cache_init(2, 8, 1, 2, jnp.float32, per_slot=True)
    c = c._replace(pos=jnp.array([1, 3], jnp.int32))
    snap = cache_spec_snapshot(c, 3)
    k_new = jnp.arange(12, dtype=jnp.float32).reshape(2, 3, 1, 2) + 1
    c2 = cache_update(c, k_new, 2 * k_new)
    assert c2.pos.tolist() == [4, 6]
    c3 = cache_spec_commit(c2, snap, jnp.array([2, 0], jnp.int32))
    assert c3.pos.tolist() == [3, 3]
    # row 0 keeps its first 2 written tokens, the third is rolled back to 0
    np.testing.assert_array_equal(np.asarray(c3.k[0, 1:3]),
                                  np.asarray(k_new[0, :2]))
    assert float(jnp.abs(c3.k[0, 3]).max()) == 0.0
    # row 1 rolled back entirely: bytes bit-equal to pre-write state
    np.testing.assert_array_equal(np.asarray(c3.k[1]), np.asarray(c.k[1]))


# --- engine-level invariants --------------------------------------------------


def test_spec_engine_self_draft_accepts_everything():
    """draft == target (two pools over one runtime): every proposal matches
    the target distribution exactly, so at temperature 0 every draft is
    accepted and accept_rate is exactly 1.0."""
    cfg, rt = _rnn_runtime()
    eng = ServeEngine(rt, cfg.vocab, slots=2, max_context=64,
                      prefill_chunk=4, draft=rt, spec_k=3)
    reqs = [Request(prompt=np.arange(5, dtype=np.int32) % cfg.vocab,
                    max_tokens=9, temperature=0.0, top_k=0, seed=7, rid=0)]
    _, m = eng.run(reqs, realtime=False)
    assert m["accept_rate"] == 1.0
    assert m["spec_traces"] == 1
    # 1 admit token + ceil(8 / (k+1)) fully-accepted rounds
    assert m["spec_rounds"] == 2


def test_spec_engine_gates_unsupported_runtimes():
    """Ring caches (gemma3 local layers) and hybrid SSMs (zamba2) cannot
    roll back a rejected suffix exactly — the engine must refuse upfront,
    not corrupt streams at runtime."""
    for arch in ("gemma3-27b", "zamba2-1.2b"):
        cfg = get_config(arch).reduced()
        rt = TransformerRuntime(cfg, T.model_init(jax.random.PRNGKey(0), cfg))
        assert not rt.spec_capable
        with pytest.raises(NotImplementedError, match="speculative"):
            ServeEngine(rt, cfg.vocab, slots=2, max_context=16,
                        draft=rt, spec_k=2)
    cfg, rt = _rnn_runtime()
    with pytest.raises(ValueError, match="spec_k"):
        ServeEngine(rt, cfg.vocab, slots=2, max_context=16, draft=rt)
    with pytest.raises(ValueError, match="spec_k"):
        ServeEngine(rt, cfg.vocab, slots=2, max_context=16, spec_k=2)
    with pytest.raises(ValueError, match="spec_k"):
        ServeEngine(rt, cfg.vocab, slots=2, max_context=16, draft=rt,
                    spec_k=-1)
    with pytest.raises(ValueError, match="draft span"):
        # a verify's quota overshoot must stay inside the caches'
        # DECODE_MARGIN slack, or the non-ring clamp could alias writes
        ServeEngine(rt, cfg.vocab, slots=2, max_context=16, draft=rt,
                    spec_k=65)


def test_speculative_draft_requires_fp_masters():
    _, rt = _rnn_runtime(packed=True)
    with pytest.raises(ValueError, match="packed"):
        speculative_draft(rt)


def test_spec_engine_warm_then_run_traces_nothing_new():
    cfg, rt = _rnn_runtime()
    draft = speculative_draft(rt, mode="ternary")
    eng = ServeEngine(rt, cfg.vocab, slots=2, max_context=64,
                      prefill_chunk=4, draft=draft, spec_k=2)
    eng.warm()
    pt, st = eng.prefill_traces, eng.spec_traces
    assert st == 1 and pt == len(eng.declared_buckets())
    rng = np.random.default_rng(11)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=int(rng.integers(1, 13))),
                    max_tokens=int(rng.integers(1, 8)), temperature=0.0,
                    top_k=0, seed=300 + i, rid=i) for i in range(5)]
    comps, m = eng.run(reqs, realtime=False)
    assert len(comps) == len(reqs)
    assert eng.prefill_traces == pt, "a prompt length traced a new prefill"
    assert eng.spec_traces == 1, "occupancy churn retraced the spec tick"
