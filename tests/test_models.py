"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
config of the same family and runs one forward + one train step on CPU,
asserting output shapes and finiteness (the assignment's required per-arch
smoke)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, applicable, whisper_dec_len
from repro.models import transformer as T
from repro.train.optimizer import OptConfig
from repro.train.train_step import make_train_step, train_state_init


def _batch(cfg, B=2, S=32):
    key = jax.random.PRNGKey(9)
    if cfg.family == "audio":
        d = max(8, S // 2)
        b = {"tokens": jax.random.randint(key, (B, d), 0, cfg.vocab),
             "targets": jax.random.randint(key, (B, d), 0, cfg.vocab),
             "enc_frames": jax.random.normal(key, (B, S, cfg.d_model))}
    else:
        b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
             "targets": jax.random.randint(key, (B, S), 0, cfg.vocab)}
        if cfg.family == "vlm":
            b["img"] = jax.random.normal(key, (B, cfg.n_img_tokens, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)

    logits, aux = T.forward(params, batch["tokens"], cfg, training=True,
                            rng=jax.random.PRNGKey(1),
                            img=batch.get("img"),
                            enc_frames=batch.get("enc_frames"))
    assert logits.shape == (*batch["tokens"].shape, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())

    opt = OptConfig(lr=1e-3)
    state = train_state_init(params, opt, jax.random.PRNGKey(2))
    step = jax.jit(make_train_step(cfg, opt))
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_dimensions_match_assignment(arch):
    """The FULL configs carry the exact assigned dimensions (exercised via
    dry-run only; here we pin the numbers so a config edit can't drift)."""
    expect = {
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
    }[arch]
    c = get_config(arch)
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == expect


def test_moe_extras():
    c = get_config("qwen3-moe-30b-a3b")
    assert (c.n_experts, c.topk) == (128, 8)
    c = get_config("mixtral-8x7b")
    assert (c.n_experts, c.topk) == (8, 2)
    assert c.swa_all and c.window == 4096


def test_long_500k_applicability_split():
    """Exactly the sub-quadratic archs run long_500k (DESIGN.md §5)."""
    eligible = {a for a in ARCH_IDS
                if applicable(get_config(a), SHAPES["long_500k"])[0]}
    assert eligible == {"gemma3-27b", "rwkv6-7b", "zamba2-1.2b", "mixtral-8x7b"}


def test_whisper_decoder_length_rule():
    assert whisper_dec_len(4096) == 448
    assert whisper_dec_len(512) == 64
    assert whisper_dec_len(32768) == 448


def test_quantized_vs_fp_configs_share_code_path():
    """Flipping quant mode changes weights' support, not shapes."""
    from repro.core.quantize import QuantSpec
    cfg = get_config("qwen3-0.6b").reduced()
    batch = _batch(cfg)
    for mode in ("none", "binary", "ternary"):
        c = cfg.with_quant(QuantSpec(mode=mode, norm="channel"))
        params = T.model_init(jax.random.PRNGKey(0), c)
        logits, _ = T.forward(params, batch["tokens"], c, training=True,
                              rng=jax.random.PRNGKey(1))
        assert bool(jnp.isfinite(logits).all())


def test_pattern_expansion_counts():
    from repro.models.transformer import expand_pattern
    pat, rep, tail = expand_pattern(get_config("gemma3-27b"))
    assert len(pat) == 6 and rep == 10 and len(tail) == 2
    pat, rep, tail = expand_pattern(get_config("zamba2-1.2b"))
    assert pat == ("mamba",) * 6 + ("shared",) and rep == 6 and tail == ("mamba",) * 2
    pat, rep, tail = expand_pattern(get_config("llama-3.2-vision-90b"))
    assert len(pat) == 5 and rep == 20 and not tail


def test_unrolled_forward_matches_scan():
    """cfg.unroll (dry-run scan-correction path) is numerically identical."""
    import dataclasses
    cfg = get_config("gemma3-27b").reduced()
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    l1, _ = T.forward(params, batch["tokens"], cfg, training=False)
    l2, _ = T.forward(params, batch["tokens"],
                      dataclasses.replace(cfg, unroll=True), training=False)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-4,
                               atol=2e-4)
