"""Sharding rules: every parameter/cache leaf of every arch gets a valid
spec on the production meshes (divisibility honored, no silent failures).
Uses AbstractMesh so no 512-device runtime is needed."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, decode_context
from repro.launch.sharding import ROW_W, param_pspec
from repro.models import transformer as T
from repro.serve.kvcache import kv_pspec
from repro.runtime import abstract_mesh, use_mesh


def _mesh(multi=False):
    # abstract_mesh bridges the AbstractMesh constructor change between
    # jax 0.4.x ((name, size) pairs) and >= 0.5 ((sizes, names))
    if multi:
        return abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    return abstract_mesh((16, 16), ("data", "model"))


def _key_struct():
    return jax.ShapeDtypeStruct((2,), jnp.uint32)  # threefry key data


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("multi", [False, True])
def test_param_specs_divide_everywhere(arch, multi):
    cfg = get_config(arch)
    mesh = _mesh(multi)
    params = jax.eval_shape(lambda k: T.model_init(k, cfg), _key_struct())

    def check(path, leaf):
        spec = param_pspec(path, leaf, mesh)
        assert len(spec) <= len(leaf.shape)
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert dim % n == 0, (path, leaf.shape, spec)
        return leaf

    jax.tree_util.tree_map_with_path(check, params)


def test_big_matmul_weights_are_actually_sharded():
    """FSDP+TP must shard every O(d^2) weight at least 16-ways."""
    cfg = get_config("llama3-8b")
    mesh = _mesh()
    params = jax.eval_shape(lambda k: T.model_init(k, cfg), _key_struct())

    def check(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name.startswith("W") and leaf.ndim >= 2 and leaf.size > 1e6:
            spec = param_pspec(path, leaf, mesh)
            ways = 1
            for ax in spec:
                if ax is None:
                    continue
                for a in (ax if isinstance(ax, tuple) else (ax,)):
                    ways *= mesh.shape[a]
            assert ways >= 16, (path, leaf.shape, spec)
        return leaf

    jax.tree_util.tree_map_with_path(check, params)


def test_row_col_split_is_consistent():
    mesh = _mesh()
    import jax.tree_util as jtu
    mk = lambda name: (jtu.DictKey(name),)
    wq = param_pspec(mk("Wq"), jax.ShapeDtypeStruct((4096, 4096), jnp.float32), mesh)
    wo = param_pspec(mk("Wo"), jax.ShapeDtypeStruct((4096, 4096), jnp.float32), mesh)
    assert wq == P("data", "model")      # column parallel
    assert wo == P("model", "data")      # row parallel


def test_moe_expert_sharding_modes():
    import jax.tree_util as jtu
    mesh = _mesh()
    path = (jtu.DictKey("moe"), jtu.DictKey("Wgate"))
    # 128 experts: EP over model
    s = param_pspec(path, jax.ShapeDtypeStruct((128, 2048, 768), jnp.float32), mesh)
    assert s == P("model", "data", None)
    # 8 experts: TP fallback inside experts
    s = param_pspec(path, jax.ShapeDtypeStruct((8, 4096, 14336), jnp.float32), mesh)
    assert s == P(None, "data", "model")
    path_d = (jtu.DictKey("moe"), jtu.DictKey("Wdown"))
    s = param_pspec(path_d, jax.ShapeDtypeStruct((8, 14336, 4096), jnp.float32), mesh)
    assert s == P(None, "model", "data")


def test_kv_policy_head_vs_length_sharding():
    mesh = _mesh()
    with use_mesh(mesh):
        # 16 kv heads on 16-way model: shard heads
        assert kv_pspec(128, 32896, 16)[2] == "model"
        # 8 kv heads: shard the length axis instead
        s = kv_pspec(128, 32896, 8)
        assert s[1] == "model" and s[2] is None
        # batch 1 (long_500k): no data sharding
        s = kv_pspec(1, 524416, 16)
        assert s[0] is None


def test_cache_shardings_cover_every_arch_decode():
    from repro.launch.sharding import cache_shardings
    mesh = jax.make_mesh((1, 1), ("data", "model"))  # real tiny mesh
    for arch in ARCH_IDS:
        cfg = get_config(arch).reduced()
        ctx, src = decode_context(cfg, 64)
        caches = jax.eval_shape(
            lambda: T.init_caches(cfg, 4, ctx, src_len=src))
        out = cache_shardings(caches, mesh)  # must not raise
        assert jax.tree.structure(out, is_leaf=lambda x: hasattr(x, "spec"))


# --- mesh serving (DESIGN.md §12) --------------------------------------------


def test_qtensor_pspecs_projection():
    """Dense-layout specs projected onto packed codes: the column entry
    always carries over; the contraction entry survives only when the
    PACKED row count divides the mesh axes and packing padded nothing."""
    from repro.core.qtensor import QTensor
    from repro.launch.sharding import qtensor_pspecs
    mesh = abstract_mesh((2, 4), ("data", "model"))

    q = QTensor.from_master(jnp.zeros((128, 64)), "ternary")  # codes (8, 64)
    cs, ss = qtensor_pspecs(P("data", "model"), q, mesh)
    assert cs == P("data", "model")      # 8 % 2 == 0, no pad: K entry kept
    assert ss is None                    # no per-channel scale

    q_pad = QTensor.from_master(jnp.zeros((120, 64)), "ternary")
    cs, _ = qtensor_pspecs(P("data", "model"), q_pad, mesh)
    assert cs == P(None, "model")        # pad rows: a shard boundary would
                                         # fall inside dequantize's pad-slice

    q_small = QTensor.from_master(jnp.zeros((48, 64)), "ternary")  # 3 rows
    cs, _ = qtensor_pspecs(P("data", "model"), q_small, mesh)
    assert cs == P(None, "model")        # 3 % 2: would split a pack word

    # leading stack axes carry over; per-output-channel scale follows the
    # column entry so dequantize's broadcast stays shard-local
    q3 = QTensor.from_master(jnp.zeros((4, 128, 64)), "ternary",
                             scale=jnp.ones((1, 1, 64)))
    cs, ss = qtensor_pspecs(P(None, "data", "model"), q3, mesh)
    assert cs == P(None, "data", "model")
    assert ss == P(None, None, "model")


def test_slot_axis_recovery():
    from repro.serve.kvcache import slot_axis
    assert slot_axis((2, 8, 48), (2, 1, 48)) == 1   # (L, B, H) rnn state
    assert slot_axis((8,), (1,)) == 0               # per-slot pos vector
    assert slot_axis((4, 16), (4, 16)) is None      # 1-slot pool
    with pytest.raises(ValueError, match="must be 1"):
        slot_axis((2, 8, 48), (2, 3, 48))


def test_serve_pool_shardings_structure():
    """Every pool leaf gets a NamedSharding keyed off its slot axis (the
    real data-axis placement is asserted on-device in test_mesh_engine)."""
    from repro.launch.sharding import serve_pool_shardings
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    pool = {"h": jnp.zeros((2, 8, 48)), "pos": jnp.zeros((8,), jnp.int32)}
    ref = {"h": jnp.zeros((2, 1, 48)), "pos": jnp.zeros((1,), jnp.int32)}
    out = serve_pool_shardings(pool, ref, mesh)
    assert set(out) == {"h", "pos"}
    assert all(hasattr(s, "spec") for s in jax.tree_util.tree_leaves(
        out, is_leaf=lambda x: hasattr(x, "spec")))
