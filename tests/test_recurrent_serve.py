"""Unified recurrent serving runtime (DESIGN.md §6): stateful prefill/decode
must reproduce the full-sequence forward, the fused Pallas decode-step kernel
must match the unfused path, and BN-LSTM, RWKV6 and Mamba2 must all serve
behind the one runtime interface."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RNN_ARCH_IDS, get_config, get_rnn_config
from repro.core import bnlstm as BL
from repro.core.quantize import QuantSpec
from repro.models import transformer as T
from repro.serve.recurrent import (RNNRuntime, TransformerRuntime,
                                   serving_runtime, state_nbytes)
from repro.serve.sampler import sample


def _rnn_cfg(cell, mode="ternary"):
    return BL.RNNConfig(vocab=24, d_hidden=48, n_layers=2, cell=cell,
                        quant=QuantSpec(mode=mode, norm="batch"))


def _variables(cfg, seed=0):
    """Init params and RANDOMIZE the BN running stats — zero means / unit
    vars would let a broken frozen-BN affine fold pass unnoticed."""
    var = BL.rnn_lm_init(jax.random.PRNGKey(seed), cfg)
    key = jax.random.PRNGKey(seed + 1)
    layers = []
    for i, ls in enumerate(var["state"]["layers"]):
        d = {}
        for j, (n, st) in enumerate(sorted(ls.items())):
            k1, k2 = jax.random.split(jax.random.fold_in(key, 10 * i + j))
            d[n] = st._replace(
                mean=0.2 * jax.random.normal(k1, st.mean.shape),
                var=0.5 + jax.random.uniform(k2, st.var.shape))
        layers.append(d)
    return {"params": var["params"], "state": {"layers": layers}}


def _packed(var, cfg):
    return {"params": BL.export_packed_rnn(var["params"], cfg),
            "state": var["state"]}


# --- prefill + N x decode_step == rnn_lm_apply -------------------------------


@pytest.mark.parametrize("cell", ["lstm", "gru"])
@pytest.mark.parametrize("packed", [False, True], ids=["fp", "packed"])
def test_stepwise_decode_matches_full_forward(cell, packed):
    cfg = _rnn_cfg(cell)
    var = _variables(cfg)
    if packed:
        var = _packed(var, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 14), 0, cfg.vocab)
    full = BL.rnn_lm_apply(var, toks, cfg, training=False)

    lg, st = BL.rnn_prefill(var, toks[:, :7], cfg)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, :7]),
                               atol=1e-5)
    assert int(st.pos) == 7
    for i in range(7):
        lg, st = BL.rnn_decode_step(var, toks[:, 7 + i], cfg, st)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, 7 + i]),
                                   atol=1e-5)
    assert int(st.pos) == 14


def test_prefill_is_resumable():
    """Two half prompts through prefill == one full prompt (state carries)."""
    cfg = _rnn_cfg("lstm")
    var = _packed(_variables(cfg), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 12), 0, cfg.vocab)
    lg_a, st = BL.rnn_prefill(var, toks[:, :6], cfg)
    lg_b, st = BL.rnn_prefill(var, toks[:, 6:], cfg, st)
    full = BL.rnn_lm_apply(var, toks, cfg, training=False)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([lg_a, lg_b], axis=1)),
        np.asarray(full), atol=1e-5)


# --- fused Pallas decode-step kernel -----------------------------------------


@pytest.mark.parametrize("cell", ["lstm", "gru"])
@pytest.mark.parametrize("mode", ["ternary", "binary"])
def test_fused_decode_step_matches_unfused(cell, mode):
    cfg = _rnn_cfg(cell, mode)
    qvar = _packed(_variables(cfg), cfg)
    # dense=False: explicit packed-tables opt-in (CPU would default dense)
    tables = BL.rnn_decode_tables(qvar, cfg, dense=False)
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 6), 0, cfg.vocab)
    st = BL.rnn_state_init(cfg, 2)
    for i in range(6):
        lg_f, st_f = BL.rnn_decode_step(qvar, toks[:, i], cfg, st,
                                        tables=tables, fused=True,
                                        interpret=True)
        lg_u, st_u = BL.rnn_decode_step(qvar, toks[:, i], cfg, st,
                                        tables=tables, fused=False)
        np.testing.assert_allclose(np.asarray(lg_f), np.asarray(lg_u),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(st_f.h), np.asarray(st_u.h),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(st_f.c), np.asarray(st_u.c),
                                   atol=1e-5)
        st = st_f  # keep walking the state off zero


def test_fused_requires_packed_weights():
    cfg = _rnn_cfg("lstm")
    var = _variables(cfg)  # fp masters — no gate codes
    st = BL.rnn_state_init(cfg, 1)
    tok = jnp.zeros((1,), jnp.int32)
    with pytest.raises(ValueError, match="fused decode"):
        BL.rnn_decode_step(var, tok, cfg, st, fused=True)


def test_decode_tables_layer0_rows_are_bn_folded():
    """The serving table gathers token rows that are ALREADY dequantized and
    BN-affine-folded — the per-call dequantize is gone."""
    cfg = _rnn_cfg("lstm")
    qvar = _packed(_variables(cfg), cfg)
    tables = BL.rnn_decode_tables(qvar, cfg, dense=False)
    assert tables[0]["rows_bn"].shape == (cfg.vocab, 4 * cfg.d_hidden)
    assert "qx" not in tables[0]          # layer 0 never re-projects
    assert "tick" in tables[0]            # whole-tick kernel artifact cached
    tick = tables[0]["tick"]
    g = tick["codes_h"]
    assert g.shape[:2] == (cfg.n_layers, cfg.n_gates)
    assert g.dtype == jnp.uint32
    assert g.shape[3] % 128 == 0           # gate boundaries tile-aligned
    # arrays only: the artifact rides through jits as a pytree argument
    assert all(hasattr(v, "dtype") for v in tick.values())


# --- the one runtime interface across families -------------------------------


def test_rnn_runtime_greedy_decode_is_consistent():
    """Greedy continuation via the runtime == teacher-forced full forward."""
    cfg = _rnn_cfg("lstm")
    qvar = _packed(_variables(cfg), cfg)
    rt = serving_runtime(cfg, qvar)
    assert isinstance(rt, RNNRuntime)
    B, S, n_new = 1, 10, 4
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0, cfg.vocab)
    st = rt.init_state(B)
    logits, st = rt.prefill(toks, st)
    seq = toks
    for _ in range(n_new):
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        seq = jnp.concatenate([seq, nxt[:, None]], axis=1)
        logits, st = rt.decode_step(nxt, st)
    full = BL.rnn_lm_apply(qvar, seq, cfg, training=False)
    for i in range(n_new):
        tf = jnp.argmax(full[:, S - 1 + i], axis=-1)
        assert int(tf[0]) == int(seq[0, S + i])
    # constant-size state: the RNN serves any context length in O(1) memory
    assert state_nbytes(st) == state_nbytes(rt.init_state(B))


@pytest.mark.parametrize("arch", ["rwkv6-7b", "zamba2-1.2b"])
def test_transformer_runtime_recurrent_archs(arch):
    """RWKV6 / Mamba2 serve behind the SAME interface: their RWKVState /
    SSMState thread through the runtime's opaque state pytree."""
    cfg = get_config(arch).reduced()
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    rt = serving_runtime(cfg, params)
    assert isinstance(rt, TransformerRuntime)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    state = rt.init_state(B, S)
    lg_pre, state = rt.prefill(toks[:, :-1], state)
    lg_dec, state = rt.decode_step(toks[:, -1], state)
    full, _ = T.forward(params, toks, cfg, training=False)
    np.testing.assert_allclose(np.asarray(lg_pre), np.asarray(full[:, -2]),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)
    assert state_nbytes(state) > 0


def test_rnn_arch_registry():
    cfg = get_rnn_config(RNN_ARCH_IDS[0])
    assert isinstance(cfg, BL.RNNConfig)
    with pytest.raises(KeyError):
        get_rnn_config("not-an-arch")


# --- sampler numerics (half-precision logits) --------------------------------


@pytest.mark.parametrize("dtype", [jnp.float16, jnp.bfloat16])
def test_sampler_halfprec_masking(dtype):
    """Masking must use the dtype's own min: -1e30 overflows fp16 to -inf."""
    logits = jnp.array([[2.0, 1.0, 99.0]], dtype)  # slot 2 is a padded slot
    for i in range(20):
        tok = int(sample(logits, jax.random.PRNGKey(i), temperature=0.9,
                         top_k=2, vocab=2)[0])
        assert tok in (0, 1)
    assert int(sample(logits, jax.random.PRNGKey(0), temperature=0.0,
                      vocab=2)[0]) == 0
