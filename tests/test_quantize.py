"""Property + unit tests for the paper's core quantizers (Eqs. 1, 4-6)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, not error, when absent
from hypothesis import given, settings, strategies as st

from repro.core import quantize as Q

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _w(seed, shape, scale=0.05):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


# --- Eq. 4-6: support + unbiasedness ----------------------------------------

@given(st.integers(0, 2**31 - 1), st.sampled_from(["binary", "ternary"]))
def test_quantized_support(seed, mode):
    """Sampled values land exactly in {-a,+a} / {-a,0,+a}."""
    w = _w(seed, (16, 24))
    alpha = Q.glorot_alpha(16, 24)
    u = jax.random.uniform(jax.random.PRNGKey(seed ^ 1), w.shape)
    q = (Q.binarize_stochastic if mode == "binary" else Q.ternarize_stochastic)(
        w, u, alpha)
    vals = {-alpha, 0.0, alpha} if mode == "ternary" else {-alpha, alpha}
    got = set(np.unique(np.asarray(q)).tolist())
    assert all(any(abs(g - v) < 1e-7 for v in vals) for g in got)


@pytest.mark.parametrize("mode", ["binary", "ternary"])
def test_stochastic_unbiased(mode):
    """E[q] == clip(w) over many noise draws (the Bernoulli construction)."""
    w = _w(0, (8, 8), scale=0.03)
    alpha = Q.glorot_alpha(8, 8)
    n = 4000
    keys = jax.random.split(jax.random.PRNGKey(1), n)
    f = Q.binarize_stochastic if mode == "binary" else Q.ternarize_stochastic
    qs = jax.vmap(lambda k: f(w, jax.random.uniform(k, w.shape), alpha))(keys)
    mean = jnp.mean(qs, axis=0)
    np.testing.assert_allclose(np.asarray(mean),
                               np.clip(np.asarray(w), -alpha, alpha),
                               atol=4 * alpha / np.sqrt(n) * 3)


def test_deterministic_matches_expectation_sign():
    w = _w(3, (32, 32))
    a = Q.glorot_alpha(32, 32)
    qb = Q.binarize_deterministic(w, a)
    assert np.all(np.sign(np.asarray(qb)) == np.where(np.asarray(w) >= 0, 1, -1))
    qt = Q.ternarize_deterministic(w, a)
    assert set(np.unique(np.asarray(qt) / a)).issubset({-1.0, 0.0, 1.0})


# --- Eq. 1: straight-through estimator --------------------------------------

def test_ste_gradient_is_identity():
    w = _w(4, (6, 6))
    a = Q.glorot_alpha(6, 6)
    u = jax.random.uniform(jax.random.PRNGKey(5), w.shape)

    def loss(w):
        q = Q.quantize(w, "ternary", a, u, stochastic=True)
        return jnp.sum(q * jnp.arange(6.0))

    g = jax.grad(loss)(w)
    expect = jnp.broadcast_to(jnp.arange(6.0), w.shape)
    np.testing.assert_allclose(np.asarray(g), np.asarray(expect), rtol=1e-6)


def test_master_clip_keeps_probabilities_valid():
    w = _w(6, (10, 10), scale=10.0)  # deliberately out of range
    a = Q.glorot_alpha(10, 10)
    wc = Q.clip_master(w, a)
    assert float(jnp.max(jnp.abs(wc))) <= a + 1e-7


# --- packing -----------------------------------------------------------------

@given(st.integers(0, 2**31 - 1), st.integers(1, 4), st.integers(1, 40))
def test_pack_unpack_ternary_roundtrip(seed, kg, n):
    k = 16 * kg
    t = jax.random.randint(jax.random.PRNGKey(seed), (k, n), -1, 2).astype(jnp.float32)
    packed = Q.pack_ternary(t)
    assert packed.shape == (k // 16, n) and packed.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(Q.unpack_ternary(packed, k)),
                                  np.asarray(t))


@given(st.integers(0, 2**31 - 1), st.integers(1, 3), st.integers(1, 33))
def test_pack_unpack_binary_roundtrip(seed, kg, n):
    k = 32 * kg
    b = jnp.where(jax.random.bernoulli(jax.random.PRNGKey(seed), 0.5, (k, n)),
                  1.0, -1.0)
    packed = Q.pack_binary(b)
    np.testing.assert_array_equal(np.asarray(Q.unpack_binary(packed, k)),
                                  np.asarray(b))


def test_packed_sizes_match_paper_ratio():
    """Paper Table 1: binary = fp32/32, ternary = fp32/16 weight bytes."""
    shape = (1024, 1024)
    fp = Q.packed_nbytes(shape, "fp32")
    assert Q.packed_nbytes(shape, "binary") == fp // 32
    assert Q.packed_nbytes(shape, "ternary") == fp // 16


# --- baselines ---------------------------------------------------------------

def test_binaryconnect_scale():
    w = _w(7, (64, 64))
    q = Q.binaryconnect(w)
    a = float(jnp.mean(jnp.abs(w)))
    assert np.allclose(np.abs(np.asarray(q)), a, rtol=1e-5)


def test_twn_threshold_sparsity():
    w = _w(8, (64, 64))
    q = np.asarray(Q.twn(w))
    frac_zero = (q == 0).mean()
    assert 0.05 < frac_zero < 0.95  # threshold keeps a nontrivial support


def test_dorefa_levels():
    w = _w(9, (32, 32))
    for bits in (2, 3, 4):
        q = np.asarray(Q.dorefa(w, bits))
        assert len(np.unique(q)) <= 2 ** bits


def test_quant_spec_bits():
    from repro.core.quantize import QuantSpec
    assert QuantSpec(mode="binary").weight_bits == 1
    assert QuantSpec(mode="ternary").weight_bits == 2
    assert QuantSpec(mode="none").weight_bits == 32
