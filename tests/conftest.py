import os

# Smoke tests and benches must see the real single CPU device; only the
# dry-run (launch/dryrun.py) forces 512 host devices, in its own process.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)
