"""Serving path: prefill + decode must reproduce the full forward exactly
(per family), ring buffers must mask correctly, MoE decode must not drop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import decode_context
from repro.models import transformer as T
from repro.serve.kvcache import AttnCache, cache_init, cache_positions, cache_update
from repro.serve.sampler import sample


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    import dataclasses
    cfg = get_config(arch).reduced()
    if cfg.n_experts:
        # decouple the consistency check from capacity-drop nondeterminism
        # (prefill sees T-1 tokens, forward sees T -> different capacities);
        # drop semantics are covered in test_moe.py
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 24
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    kw = {}
    ctx, src = decode_context(cfg, S)
    if cfg.family == "vlm":
        kw["img"] = jax.random.normal(jax.random.PRNGKey(3),
                                      (B, cfg.n_img_tokens, cfg.d_model))
        src = cfg.n_img_tokens
    if cfg.family == "audio":
        kw["enc_frames"] = jax.random.normal(jax.random.PRNGKey(3),
                                             (B, S, cfg.d_model))
        tokens = tokens[:, :12]
        ctx = 12

    caches = T.init_caches(cfg, B, ctx, src_len=src, dtype=jnp.float32)
    lg_pre, caches = T.prefill(params, tokens[:, :-1], caches, cfg, **kw)
    lg_dec, caches = T.decode_step(params, tokens[:, -1], caches, cfg)
    lg_full, _ = T.forward(params, tokens, cfg, training=False, **kw)
    np.testing.assert_allclose(np.asarray(lg_pre), np.asarray(lg_full[:, -2]),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(lg_full[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_multi_step_decode_consistency():
    """Greedy continuation via decode == teacher-forced forward argmax."""
    cfg = get_config("qwen3-1.7b").reduced()
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    B, S, n_new = 1, 16, 4
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    caches = T.init_caches(cfg, B, S + n_new, dtype=jnp.float32)
    logits, caches = T.prefill(params, tokens, caches, cfg)
    seq = tokens
    for _ in range(n_new):
        nxt = jnp.argmax(logits[:, :cfg.vocab], axis=-1).astype(jnp.int32)
        seq = jnp.concatenate([seq, nxt[:, None]], axis=1)
        logits, caches = T.decode_step(params, nxt, caches, cfg)
    # teacher-forced check of the SAME sequence
    full, _ = T.forward(params, seq, cfg, training=False)
    for i in range(n_new):
        tf = jnp.argmax(full[:, S - 1 + i, :cfg.vocab], axis=-1)
        assert int(tf[0]) == int(seq[0, S + i])


# --- ring buffer -------------------------------------------------------------

def test_ring_cache_positions():
    c = cache_init(1, 4, 1, 2, jnp.float32, ring=True)
    assert np.all(np.asarray(cache_positions(c)) == -1)
    for t in range(6):
        c = cache_update(c, jnp.full((1, 1, 1, 2), float(t)),
                         jnp.full((1, 1, 1, 2), float(t)))
    pos = np.asarray(cache_positions(c))
    # after 6 writes into 4 slots: slots hold positions 4,5,2,3
    assert sorted(pos.tolist()) == [2, 3, 4, 5]
    # slot contents match their claimed positions
    for s, p in enumerate(pos):
        assert float(c.k[0, s, 0, 0]) == float(p)


def test_ring_prefill_keeps_last_window():
    c = cache_init(1, 4, 1, 1, jnp.float32, ring=True)
    k = jnp.arange(10.0).reshape(1, 10, 1, 1)
    c = cache_update(c, k, k)
    pos = np.asarray(cache_positions(c))
    assert sorted(pos.tolist()) == [6, 7, 8, 9]
    for s, p in enumerate(pos):
        assert float(c.k[0, s, 0, 0]) == float(p)


def test_linear_cache_append_and_mask():
    c = cache_init(2, 8, 1, 2, jnp.float32)
    c = cache_update(c, jnp.ones((2, 3, 1, 2)), jnp.ones((2, 3, 1, 2)))
    pos = np.asarray(cache_positions(c))
    assert pos.tolist() == [0, 1, 2, -1, -1, -1, -1, -1]
    assert int(c.pos) == 3


def test_cache_is_pytree_with_static_ring_flag():
    c = cache_init(1, 4, 1, 2, jnp.float32, ring=True)
    leaves, treedef = jax.tree_util.tree_flatten(c)
    assert len(leaves) == 3  # k, v, pos — ring stays aux metadata
    c2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert c2.ring is True


def test_swa_arch_uses_ring_cache_smaller_than_context():
    cfg = get_config("mixtral-8x7b").reduced()
    caches = T.init_caches(cfg, 1, 4096, dtype=jnp.float32)
    attn = caches["stack"][0]["attn"]
    assert attn.ring
    assert attn.k.shape[2] <= cfg.window + T.DECODE_MARGIN


# --- sampler -----------------------------------------------------------------

def test_sampler_greedy_and_topk():
    logits = jnp.array([[0.1, 3.0, -1.0, 2.0]])
    assert int(sample(logits, jax.random.PRNGKey(0), temperature=0.0)[0]) == 1
    draws = {int(sample(logits, jax.random.PRNGKey(i), temperature=1.0,
                        top_k=2)[0]) for i in range(50)}
    assert draws.issubset({1, 3})


def test_sampler_vocab_mask():
    logits = jnp.array([[0.0, 1.0, 99.0]])  # index 2 is a padded slot
    tok = sample(logits, jax.random.PRNGKey(0), temperature=0.0, vocab=2)
    assert int(tok[0]) == 1
