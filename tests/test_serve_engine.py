"""Continuous-batching serve engine (DESIGN.md §7): slot surgery must be
exact, the decode tick must compile once regardless of occupancy churn, and
a request's token stream through the engine must be BYTE-IDENTICAL to
running it alone through the sequential `drive_session` loop — continuous
batching changes the schedule, never the tokens."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import bnlstm as BL
from repro.core.quantize import QuantSpec
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine, tree_write_slot
from repro.serve.kvcache import (cache_init, cache_positions, cache_reset_slots,
                                 cache_update, cache_write_slot)
from repro.serve.recurrent import (RNNRuntime, TransformerRuntime,
                                   drive_session, serving_runtime)
from repro.serve.sampler import sample, sample_slots


def _rnn_cfg(cell, mode="ternary"):
    return BL.RNNConfig(vocab=24, d_hidden=48, n_layers=2, cell=cell,
                        quant=QuantSpec(mode=mode, norm="batch"))


def _rnn_runtime(cell, packed=True, seed=0):
    cfg = _rnn_cfg(cell) if packed else dataclasses.replace(
        _rnn_cfg(cell), quant=QuantSpec(mode="none"))
    var = BL.rnn_lm_init(jax.random.PRNGKey(seed), cfg)
    params = var["params"]
    if packed:
        params = BL.export_packed_rnn(params, cfg)
    return cfg, RNNRuntime(cfg, {"params": params, "state": var["state"]})


def _requests(vocab, n, *, rng_seed=0, max_prompt=10, max_gen=8):
    rng = np.random.default_rng(rng_seed)
    return [Request(prompt=rng.integers(0, vocab,
                                        size=int(rng.integers(2, max_prompt))),
                    max_tokens=int(rng.integers(1, max_gen)),
                    temperature=0.8, top_k=5, seed=100 + i, rid=i)
            for i in range(n)]


# --- per-slot sampler: bit-parity with the scalar path -----------------------


def test_sample_slots_matches_scalar_sample_per_row():
    logits = jax.random.normal(jax.random.PRNGKey(3), (6, 33))
    temps = jnp.array([0.8, 0.0, 1.3, 0.5, 2.0, 0.8])
    topks = jnp.array([4, 0, 0, 7, 2, 33], jnp.int32)
    keys = jnp.stack([jax.random.PRNGKey(i) for i in range(6)])
    vec = sample_slots(logits, keys, temperature=temps, top_k=topks, vocab=30)
    for i in range(6):
        ref = sample(logits[i:i + 1], keys[i], temperature=float(temps[i]),
                     top_k=int(topks[i]), vocab=30)[0]
        assert int(ref) == int(vec[i])


# --- slot surgery ------------------------------------------------------------


def test_rnn_write_and_reset_slots():
    from repro.serve.engine import tree_reset_slots
    cfg = _rnn_cfg("lstm")
    pool = BL.rnn_state_init(cfg, 4, per_slot=True)
    assert pool.pos.shape == (4,)
    sub = BL.RNNState(h=jnp.ones((cfg.n_layers, 1, cfg.d_hidden)),
                      c=2 * jnp.ones((cfg.n_layers, 1, cfg.d_hidden)),
                      pos=jnp.array([7], jnp.int32))
    pool = BL.rnn_write_slots(pool, sub, 2)
    assert float(pool.h[:, 2].min()) == 1.0 and float(pool.c[:, 2].max()) == 2.0
    assert pool.pos.tolist() == [0, 0, 7, 0]
    assert float(jnp.abs(pool.h[:, [0, 1, 3]]).max()) == 0.0  # others untouched
    # the engine's shape-aware scrub (the ONE retire path) zeroes h/c/pos
    ref = BL.rnn_state_init(cfg, 1, per_slot=True)
    pool = tree_reset_slots(pool, ref, jnp.array([False, False, True, False]))
    assert float(jnp.abs(pool.h).max()) == 0.0
    assert pool.pos.tolist() == [0, 0, 0, 0]


def test_cache_write_slot_and_reset():
    pool = cache_init(3, 8, 2, 4, jnp.float32, per_slot=True)
    sub = cache_init(1, 8, 2, 4, jnp.float32, per_slot=True)
    k = jnp.ones((1, 5, 2, 4))
    sub = cache_update(sub, k, 2 * k)  # write 5 tokens into the B=1 cache
    assert sub.pos.tolist() == [5]
    pool = cache_write_slot(pool, sub, 1)
    assert pool.pos.tolist() == [0, 5, 0]
    np.testing.assert_array_equal(np.asarray(pool.k[1]), np.asarray(sub.k[0]))
    kv = cache_positions(pool)  # (B, cap): only slot 1 has valid positions
    assert kv.shape == (3, 8)
    assert kv[1].tolist() == [0, 1, 2, 3, 4, -1, -1, -1]
    assert kv[0].tolist() == [-1] * 8
    pool = cache_reset_slots(pool, jnp.array([False, True, False]))
    assert pool.pos.tolist() == [0, 0, 0]
    assert cache_positions(pool)[1].tolist() == [-1] * 8  # masked, not resliced


def test_per_slot_cache_update_rows_are_independent():
    """Decode appends at each slot's OWN depth (the mixed-length invariant)."""
    pool = cache_init(3, 6, 1, 2, jnp.float32, per_slot=True)
    pool = pool._replace(pos=jnp.array([0, 2, 5], jnp.int32))
    k1 = jnp.arange(6, dtype=jnp.float32).reshape(3, 1, 1, 2) + 1
    pool = cache_update(pool, k1, k1)
    assert pool.pos.tolist() == [1, 3, 6]
    assert float(pool.k[0, 0, 0, 0]) == 1.0
    assert float(pool.k[1, 2, 0, 0]) == 3.0
    assert float(pool.k[2, 5, 0, 0]) == 5.0


def test_tree_write_slot_transformer_pool():
    """The generic writer finds the slot axis of every stacked cache leaf
    (axis 1 behind the layer stack, axis 0 for tail caches / pos)."""
    cfg = get_config("qwen3-0.6b").reduced()
    pool = T.init_caches(cfg, 3, 16, dtype=jnp.float32, per_slot=True)
    sub = T.init_caches(cfg, 1, 16, dtype=jnp.float32, per_slot=True)
    sub = jax.tree.map(lambda a: jnp.ones_like(a), sub)
    out = tree_write_slot(pool, sub, 1)
    for p, s in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(sub)):
        ax = next(i for i, (a, b) in enumerate(zip(p.shape, s.shape)) if a != b)
        row = jnp.take(p, 1, axis=ax).astype(jnp.float32)
        others = jnp.take(p, jnp.array([0, 2]), axis=ax).astype(jnp.float32)
        assert float(jnp.abs(row - 1.0).max()) == 0.0   # slot 1 took the sub
        assert float(jnp.abs(others).max()) == 0.0      # 0/2 untouched


# --- live-mask: dead slots are frozen bit-for-bit ----------------------------


@pytest.mark.parametrize("cell", ["lstm", "gru"])
@pytest.mark.parametrize("packed", [True, False], ids=["fused", "unfused"])
def test_decode_step_live_mask_freezes_dead_slots(cell, packed):
    cfg, rt = _rnn_runtime(cell, packed=packed)
    st = BL.rnn_state_init(cfg, 3, per_slot=True)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 3), 0, cfg.vocab)
    # walk all slots off zero first
    for i in range(2):
        _, st = rt.decode_fn(toks[i], st)
    live = jnp.array([True, False, True])
    lg, st2 = rt.decode_fn(toks[2], st, live)
    # dead slot 1: h/c/pos bit-identical
    np.testing.assert_array_equal(np.asarray(st2.h[:, 1]), np.asarray(st.h[:, 1]))
    np.testing.assert_array_equal(np.asarray(st2.c[:, 1]), np.asarray(st.c[:, 1]))
    assert st2.pos.tolist() == [3, 2, 3]
    # live slots: bit-identical to an unmasked step
    lg_all, st_all = rt.decode_fn(toks[2], st)
    np.testing.assert_array_equal(np.asarray(st2.h[:, 0]), np.asarray(st_all.h[:, 0]))
    np.testing.assert_array_equal(np.asarray(st2.h[:, 2]), np.asarray(st_all.h[:, 2]))
    np.testing.assert_array_equal(np.asarray(lg[0]), np.asarray(lg_all[0]))


# --- the acceptance bar: engine == sequential, token for token ---------------


@pytest.mark.parametrize("cell,packed", [("lstm", True), ("lstm", False),
                                         ("gru", True)],
                         ids=["lstm-packed", "lstm-fp", "gru-packed"])
def test_engine_matches_sequential_rnn(cell, packed):
    cfg, rt = _rnn_runtime(cell, packed=packed)
    reqs = _requests(cfg.vocab, 7, rng_seed=3)
    # prefill_chunk=4 < max prompt: the parity bar covers CHUNKED in-slot
    # prefill (multi-chunk prompts, bucket-padded tails), not just decode
    eng = ServeEngine(rt, cfg.vocab, slots=3, max_context=64,
                      prefill_chunk=4)
    comps, m = eng.run([dataclasses.replace(r) for r in reqs], realtime=False)
    assert m["requests"] == len(reqs)
    by_rid = {c.rid: c for c in comps}
    for r in reqs:
        out, _ = drive_session(
            rt, jnp.asarray(np.asarray(r.prompt, np.int32))[None], cfg.vocab,
            gen=r.max_tokens, temperature=r.temperature, top_k=r.top_k,
            seed=r.seed)
        assert by_rid[r.rid].tokens == out[0].tolist()  # atol 0: identical


@pytest.mark.parametrize("packed", [False, True], ids=["fp", "packed"])
def test_engine_matches_sequential_transformer(packed):
    cfg = get_config("qwen3-0.6b").reduced()
    if packed:
        cfg = cfg.with_quant(QuantSpec(mode="ternary", norm="channel"))
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    if packed:
        from repro.core.qtensor import export_packed
        params = export_packed(params, cfg.quant)
    rt = TransformerRuntime(cfg, params)
    reqs = _requests(cfg.vocab, 4, rng_seed=5, max_prompt=8, max_gen=5)
    CTX = 48
    eng = ServeEngine(rt, cfg.vocab, slots=2, max_context=CTX,
                      prefill_chunk=3)
    comps, _ = eng.run([dataclasses.replace(r) for r in reqs], realtime=False)
    by_rid = {c.rid: c for c in comps}
    for r in reqs:
        # same provisioned context so the sequential baseline attends over
        # an identically-sized (masked) cache
        out, _ = drive_session(
            rt, jnp.asarray(np.asarray(r.prompt, np.int32))[None], cfg.vocab,
            gen=r.max_tokens, temperature=r.temperature, top_k=r.top_k,
            seed=r.seed, context=CTX)
        assert by_rid[r.rid].tokens == out[0].tolist()


def test_engine_matches_sequential_ring_cache():
    """gemma3's local layers use ring (sliding-window) KV buffers: the
    per-slot scatter append + per-slot ring cache_positions must reproduce
    the scalar lockstep path token-for-token."""
    cfg = get_config("gemma3-27b").reduced()
    assert "local" in cfg.block_pattern  # the arch actually exercises rings
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    rt = TransformerRuntime(cfg, params)
    reqs = _requests(cfg.vocab, 3, rng_seed=7, max_prompt=7, max_gen=4)
    CTX = 24
    # ring caches chunk at exact lengths (no bucket padding: pad writes
    # would recycle in-window slots) — still multi-chunk at chunk 3
    eng = ServeEngine(rt, cfg.vocab, slots=2, max_context=CTX,
                      prefill_chunk=3)
    comps, _ = eng.run([dataclasses.replace(r) for r in reqs], realtime=False)
    by_rid = {c.rid: c for c in comps}
    for r in reqs:
        out, _ = drive_session(
            rt, jnp.asarray(np.asarray(r.prompt, np.int32))[None], cfg.vocab,
            gen=r.max_tokens, temperature=r.temperature, top_k=r.top_k,
            seed=r.seed, context=CTX)
        assert by_rid[r.rid].tokens == out[0].tolist()


def test_engine_staggered_arrivals_change_schedule_not_tokens():
    """Arrival order / slot assignment must not leak into any stream."""
    cfg, rt = _rnn_runtime("lstm")
    reqs = _requests(cfg.vocab, 6, rng_seed=11)
    for i, r in enumerate(reqs):
        r.arrival_s = 0.01 * (len(reqs) - i)  # reversed admission order
    a, _ = ServeEngine(rt, cfg.vocab, slots=2, max_context=64).run(
        [dataclasses.replace(r) for r in reqs], realtime=False)
    for r in reqs:
        r.arrival_s = 0.0
    b, _ = ServeEngine(rt, cfg.vocab, slots=3, max_context=64).run(
        [dataclasses.replace(r) for r in reqs], realtime=False)
    ta = {c.rid: c.tokens for c in a}
    tb = {c.rid: c.tokens for c in b}
    assert ta == tb


def test_engine_eos_retires_slot():
    cfg, rt = _rnn_runtime("lstm")
    probe, _ = drive_session(rt, jnp.zeros((1, 3), jnp.int32), cfg.vocab,
                             gen=6, temperature=0.8, top_k=0, seed=42)
    stream = probe[0].tolist()
    eos = stream[2]  # force an EOS hit mid-stream
    eng = ServeEngine(rt, cfg.vocab, slots=2, max_context=64, eos_id=eos)
    comps, _ = eng.run([Request(prompt=np.zeros(3, np.int64), max_tokens=6,
                                temperature=0.8, top_k=0, seed=42)],
                       realtime=False)
    c = comps[0]
    assert c.finished == "eos"
    assert c.tokens == stream[:c.tokens.index(eos) + 1]
    assert not eng._live_host.any()


def test_engine_rejects_invalid_requests_upfront():
    """A bad request must fail BEFORE anything is in flight (never mid-run),
    and the engine must never mutate the caller's Request objects."""
    cfg, rt = _rnn_runtime("lstm")
    eng = ServeEngine(rt, cfg.vocab, slots=2, max_context=8)
    with pytest.raises(ValueError, match="max_tokens"):
        eng.run([Request(prompt=np.zeros(2, np.int32), max_tokens=0)])
    with pytest.raises(ValueError, match="max_context"):
        eng.run([Request(prompt=np.zeros(6, np.int32), max_tokens=8)])
    r = Request(prompt=np.zeros(2, np.int32), max_tokens=2)
    comps, _ = eng.run([r], realtime=False)
    assert r.rid is None and comps[0].rid == 0


# --- chunked in-slot prefill units -------------------------------------------


@pytest.mark.parametrize("cell", ["lstm", "gru"])
def test_rnn_prefill_chunk_matches_prefill(cell):
    """A bucket-padded chunk sequence == one unpadded rnn_prefill, bit for
    bit: state after the real tokens, logits at the last real token."""
    cfg, rt = _rnn_runtime(cell)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 11), 0, cfg.vocab)
    st_ref = BL.rnn_state_init(cfg, 1, per_slot=True)
    _, st_ref = BL.rnn_prefill(rt.variables, toks, cfg, st_ref,
                               tables=rt.tables)
    lg_ref = BL.rnn_logits_last(rt.variables, st_ref, cfg)
    st = BL.rnn_state_init(cfg, 1, per_slot=True)
    for lo, hi, bucket in [(0, 4, 4), (4, 8, 4), (8, 11, 4)]:  # 3 real, pad 1
        pad = jnp.zeros((1, bucket), toks.dtype)
        chunk = jax.lax.dynamic_update_slice(pad, toks[:, lo:hi], (0, 0))
        lg, st = BL.rnn_prefill_chunk(rt.variables, chunk, cfg, st,
                                      n=hi - lo, tables=rt.tables)
    np.testing.assert_array_equal(np.asarray(st.h), np.asarray(st_ref.h))
    np.testing.assert_array_equal(np.asarray(st.c), np.asarray(st_ref.c))
    assert st.pos.tolist() == [11]
    np.testing.assert_array_equal(np.asarray(lg), np.asarray(lg_ref))


def test_tree_gather_slot_inverts_tree_write_slot():
    """read half of the in-slot surgery: gather(write(pool, sub, s), s) == sub
    for every leaf of a transformer cache pool (stacked + tail axes)."""
    from repro.serve.engine import tree_gather_slot
    cfg = get_config("qwen3-0.6b").reduced()
    pool = T.init_caches(cfg, 3, 16, dtype=jnp.float32, per_slot=True)
    ref = jax.eval_shape(
        lambda: T.init_caches(cfg, 1, 16, dtype=jnp.float32, per_slot=True))
    sub = T.init_caches(cfg, 1, 16, dtype=jnp.float32, per_slot=True)
    sub = jax.tree.map(lambda a: jnp.ones_like(a), sub)
    pool = tree_write_slot(pool, sub, 2)
    back = tree_gather_slot(pool, ref, 2)
    for a, b in zip(jax.tree_util.tree_leaves(back),
                    jax.tree_util.tree_leaves(sub)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_transformer_decode_live_mask_freezes_dead_rows():
    """The decode tick must not touch a dead row's cache: with in-slot
    prefill a dead row can be MID-PREFILL, so zombie appends (bytes OR pos)
    would corrupt the prompt it is accumulating."""
    cfg = get_config("qwen3-0.6b").reduced()
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    rt = TransformerRuntime(cfg, params)
    st = rt.init_state(3, 16, per_slot=True)
    toks = jax.random.randint(jax.random.PRNGKey(1), (3, 3), 0, cfg.vocab)
    _, st = rt.prefill(toks, st)
    live = jnp.array([True, False, True])
    _, st2 = jax.jit(rt.decode_fn)(jnp.array([1, 2, 3]), st, live)
    # dead row 1: every cache leaf bit-identical; live rows advanced pos
    ref = jax.eval_shape(lambda: rt.init_state(1, 16, per_slot=True))
    from repro.serve.engine import tree_gather_slot
    row_before = tree_gather_slot(st, ref, 1)
    row_after = tree_gather_slot(st2, ref, 1)
    for a, b in zip(jax.tree_util.tree_leaves(row_before),
                    jax.tree_util.tree_leaves(row_after)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    live_rows = tree_gather_slot(st2, ref, 0)
    prev_rows = tree_gather_slot(st, ref, 0)
    changed = any(not np.array_equal(np.asarray(a), np.asarray(b))
                  for a, b in zip(jax.tree_util.tree_leaves(live_rows),
                                  jax.tree_util.tree_leaves(prev_rows)))
    assert changed  # live rows really stepped


def test_engine_matches_sequential_hybrid_ssm():
    """zamba2 (mamba + shared attention): 'whole' chunk granularity, and
    the decode tick's recurrent-state freeze (_freeze_dead) must keep a
    dead slot's S-matrices / conv tails bit-frozen — with in-slot prefill
    a dead row can be mid-prefill, so this is load-bearing, not cosmetic."""
    from repro.serve.engine import tree_gather_slot
    cfg = get_config("zamba2-1.2b").reduced()
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    rt = TransformerRuntime(cfg, params)
    assert rt.chunk_granularity == "whole" and not rt.pad_buckets

    # dead-row freeze across EVERY pool leaf (ssm h/conv/pos included)
    st = rt.init_state(2, 16, per_slot=True)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 3), 0, cfg.vocab)
    _, st = rt.prefill(toks, st)
    _, st2 = jax.jit(rt.decode_fn)(jnp.array([1, 2]), st,
                                   jnp.array([False, True]))
    ref = jax.eval_shape(lambda: rt.init_state(1, 16, per_slot=True))
    for a, b in zip(jax.tree_util.tree_leaves(tree_gather_slot(st, ref, 0)),
                    jax.tree_util.tree_leaves(tree_gather_slot(st2, ref, 0))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # and the engine over the hybrid still streams byte-identically
    reqs = _requests(cfg.vocab, 2, rng_seed=37, max_prompt=6, max_gen=4)
    CTX = 20
    eng = ServeEngine(rt, cfg.vocab, slots=2, max_context=CTX,
                      prefill_chunk=4)
    comps, m = eng.run([dataclasses.replace(r) for r in reqs],
                       realtime=False)
    assert m["tick_traces"] == 1
    by_rid = {c.rid: c for c in comps}
    for r in reqs:
        out, _ = drive_session(
            rt, jnp.asarray(np.asarray(r.prompt, np.int32))[None], cfg.vocab,
            gen=r.max_tokens, temperature=r.temperature, top_k=r.top_k,
            seed=r.seed, context=CTX)
        assert by_rid[r.rid].tokens == out[0].tolist()


# --- TTFT semantics + scheduling guarantees ----------------------------------


def test_completion_timestamps_are_ordered():
    """t_submit <= t_admit <= t_first <= t_done for every completion of a
    mixed realtime workload — t_first is stamped when the first token is
    actually sampled (after the last prompt chunk), not at admission."""
    cfg, rt = _rnn_runtime("lstm")
    reqs = _requests(cfg.vocab, 6, rng_seed=13, max_prompt=12)
    for i, r in enumerate(reqs):
        r.arrival_s = 0.002 * i
    eng = ServeEngine(rt, cfg.vocab, slots=2, max_context=64,
                      prefill_chunk=4)
    comps, _ = eng.run(reqs, realtime=True)
    assert len(comps) == len(reqs)
    for c in comps:
        assert c.t_submit <= c.t_admit <= c.t_first <= c.t_done
        assert c.ttft_s >= 0 and c.queue_s >= 0
    # multi-chunk prompts really did sample their first token after admit
    long = [c for c in comps if c.prompt_len > 4]
    assert long and all(c.t_first > c.t_admit for c in long)


def test_long_prompt_does_not_stall_decodes():
    """Head-of-line blocking is gone: while a 40-token prompt prefills in
    2-token chunks, a live short request keeps decoding every tick and
    finishes BEFORE the long prompt's first token; no admission ever runs
    more than one chunk between decode ticks."""
    cfg, rt = _rnn_runtime("lstm")
    eng = ServeEngine(rt, cfg.vocab, slots=2, max_context=64,
                      prefill_chunk=2)
    rng = np.random.default_rng(0)
    short = Request(prompt=rng.integers(0, cfg.vocab, size=2), max_tokens=6,
                    temperature=0.8, top_k=5, seed=7, rid=0, arrival_s=0.0)
    long = Request(prompt=rng.integers(0, cfg.vocab, size=40), max_tokens=2,
                   temperature=0.8, top_k=5, seed=8, rid=1, arrival_s=0.0)
    comps, m = eng.run([short, long], realtime=False)
    assert m["max_decode_stall_ticks"] <= 1
    by = {c.rid: c for c in comps}
    assert by[0].t_done < by[1].t_first  # short finished mid-long-prefill
    for r in (short, long):  # and the interleaving changed no bytes
        out, _ = drive_session(
            rt, jnp.asarray(np.asarray(r.prompt, np.int32))[None], cfg.vocab,
            gen=r.max_tokens, temperature=r.temperature, top_k=r.top_k,
            seed=r.seed)
        assert by[r.rid].tokens == out[0].tolist()


# --- engine edge cases -------------------------------------------------------


def test_prompt_exactly_fills_context():
    """prompt == max_context - 1 with max_tokens == 1 is the largest legal
    request; it must admit, chunk, sample and retire cleanly."""
    cfg, rt = _rnn_runtime("lstm")
    CTX = 16
    eng = ServeEngine(rt, cfg.vocab, slots=2, max_context=CTX,
                      prefill_chunk=4)
    prompt = np.arange(CTX - 1, dtype=np.int32) % cfg.vocab
    comps, _ = eng.run([Request(prompt=prompt, max_tokens=1, seed=3, rid=0)],
                       realtime=False)
    out, _ = drive_session(rt, jnp.asarray(prompt)[None], cfg.vocab, gen=1,
                           temperature=0.8, top_k=0, seed=3)
    assert comps[0].tokens == out[0].tolist()
    assert comps[0].finished == "length"
    assert not eng._live_host.any() and eng._free_slot() == 0


def test_eos_on_admission_token():
    """EOS hit by the very first sampled token: the request completes at
    prefill time without ever occupying a decode tick."""
    cfg, rt = _rnn_runtime("lstm")
    probe, _ = drive_session(rt, jnp.zeros((1, 5), jnp.int32), cfg.vocab,
                             gen=1, temperature=0.8, top_k=0, seed=11)
    eos = probe[0].tolist()[0]
    eng = ServeEngine(rt, cfg.vocab, slots=2, max_context=64, eos_id=eos,
                      prefill_chunk=2)
    ticks0 = eng.ticks
    comps, _ = eng.run([Request(prompt=np.zeros(5, np.int32), max_tokens=8,
                                temperature=0.8, top_k=0, seed=11)],
                       realtime=False)
    assert comps[0].finished == "eos" and comps[0].tokens == [eos]
    assert eng.ticks == ticks0  # never decoded
    assert comps[0].t_first == comps[0].t_done
    assert eng._free_slot() == 0


def test_rejected_request_does_not_poison_inflight_workload():
    """Validation fails BEFORE anything enters a slot, and a rejected
    run() leaves the engine fully serviceable: the next workload still
    matches the sequential oracle with the tick never retracing."""
    cfg, rt = _rnn_runtime("lstm")
    eng = ServeEngine(rt, cfg.vocab, slots=2, max_context=16,
                      prefill_chunk=4)
    good = _requests(cfg.vocab, 3, rng_seed=17, max_prompt=8, max_gen=5)
    bad = Request(prompt=np.zeros(14, np.int32), max_tokens=8)  # 14+8 > 16
    with pytest.raises(ValueError, match="max_context"):
        eng.run([dataclasses.replace(good[0]), bad], realtime=False)
    assert not eng._live_host.any() and not eng._prefill_q
    comps, m = eng.run([dataclasses.replace(r) for r in good],
                       realtime=False)
    assert m["tick_traces"] == 1
    by_rid = {c.rid: c for c in comps}
    for r in good:
        out, _ = drive_session(
            rt, jnp.asarray(np.asarray(r.prompt, np.int32))[None], cfg.vocab,
            gen=r.max_tokens, temperature=r.temperature, top_k=r.top_k,
            seed=r.seed)
        assert by_rid[r.rid].tokens == out[0].tolist()


def test_run_twice_reuses_slots_cleanly():
    """Back-to-back workloads on ONE engine: freed slots are scrubbed and
    reused, and the second wave's streams still match the oracle exactly
    (nothing from wave 1 leaks through a reused slot row)."""
    cfg, rt = _rnn_runtime("lstm")
    eng = ServeEngine(rt, cfg.vocab, slots=2, max_context=64,
                      prefill_chunk=4)
    eng.run(_requests(cfg.vocab, 4, rng_seed=19), realtime=False)
    wave2 = _requests(cfg.vocab, 4, rng_seed=23)
    comps, m = eng.run([dataclasses.replace(r) for r in wave2],
                       realtime=False)
    assert m["tick_traces"] == 1
    by_rid = {c.rid: c for c in comps}
    for r in wave2:
        out, _ = drive_session(
            rt, jnp.asarray(np.asarray(r.prompt, np.int32))[None], cfg.vocab,
            gen=r.max_tokens, temperature=r.temperature, top_k=r.top_k,
            seed=r.seed)
        assert by_rid[r.rid].tokens == out[0].tolist()
    assert {c.slot for c in comps} <= {0, 1}  # same two slots, recycled


# --- the compile-once invariant ----------------------------------------------


@pytest.mark.parametrize("family", ["rnn", "qwen3"])
def test_warm_buckets_then_run_traces_nothing(family):
    """After warm() compiles the declared chunk buckets, a measured run()
    performs ZERO new traces — prefill included, not just the decode tick.
    Bucket padding is what makes the declared set traffic-independent."""
    if family == "rnn":
        cfg, rt = _rnn_runtime("lstm")
        vocab, ctx = cfg.vocab, 64
        reqs = _requests(vocab, 6, rng_seed=29, max_prompt=13)
    else:
        cfg = get_config("qwen3-0.6b").reduced()
        params = T.model_init(jax.random.PRNGKey(0), cfg)
        rt = TransformerRuntime(cfg, params)
        vocab, ctx = cfg.vocab, 32
        reqs = _requests(vocab, 3, rng_seed=29, max_prompt=9, max_gen=4)
    eng = ServeEngine(rt, vocab, slots=2, max_context=ctx, prefill_chunk=4)
    eng.warm()  # NO prompt lengths: the declared buckets must suffice
    pt, tt = eng.prefill_traces, eng.tick_traces
    assert tt == 1 and pt == len(eng.declared_buckets())
    comps, m = eng.run(reqs, realtime=False)
    assert len(comps) == len(reqs)
    assert eng.prefill_traces == pt, "a prompt length traced a new prefill"
    assert eng.tick_traces == 1, "occupancy churn retraced the tick"


def test_tick_compiles_once_across_occupancy_churn():
    """Admits and retires between ticks must NOT retrace the decode tick —
    occupancy is an array value, not a shape."""
    cfg, rt = _rnn_runtime("lstm")
    eng = ServeEngine(rt, cfg.vocab, slots=3, max_context=64)
    # wave 1: overfull queue -> admission churn as slots free up
    eng.run(_requests(cfg.vocab, 5, rng_seed=21), realtime=False)
    assert eng.tick_traces == 1
    # wave 2: different occupancy pattern on the SAME engine
    eng.run(_requests(cfg.vocab, 2, rng_seed=22, max_gen=4), realtime=False)
    assert eng.tick_traces == 1
    assert eng.ticks > 2


def test_pool_state_is_constant_shape():
    """mask-don't-reshape: the pool pytree never changes shape over a run."""
    cfg, rt = _rnn_runtime("lstm")
    eng = ServeEngine(rt, cfg.vocab, slots=2, max_context=64)
    shapes0 = [l.shape for l in jax.tree_util.tree_leaves(eng.pool)]
    eng.run(_requests(cfg.vocab, 4, rng_seed=31), realtime=False)
    assert [l.shape for l in jax.tree_util.tree_leaves(eng.pool)] == shapes0


def test_params_are_jit_arguments_not_baked_constants():
    """The engine's tick/prefill jits take the weight tree as an ARGUMENT
    (`rt.jit_prm`), never a closure capture: closed-over weights get
    constant-folded by XLA, which shifts logits ~1ulp against the
    arg-passed `drive_session` jits and makes logits-level comparisons
    unsound.  The observable property: swapping in a differently-
    initialised tree of the same shape changes the streams WITHOUT a
    single new trace — impossible if the weights were baked in."""
    cell = "lstm"
    cfg = dataclasses.replace(_rnn_cfg(cell), quant=QuantSpec(mode="none"))
    var1 = BL.rnn_lm_init(jax.random.PRNGKey(0), cfg)
    var2 = BL.rnn_lm_init(jax.random.PRNGKey(9), cfg)
    rt1 = RNNRuntime(cfg, {"params": var1["params"], "state": var1["state"]})
    rt2 = RNNRuntime(cfg, {"params": var2["params"], "state": var2["state"]})
    eng = ServeEngine(rt1, cfg.vocab, slots=1, max_context=64,
                      prefill_chunk=4)
    req = Request(prompt=np.arange(8, dtype=np.int32) % cfg.vocab,
                  max_tokens=10, temperature=0.0, top_k=0, seed=5)
    c1, _ = eng.run([dataclasses.replace(req)], realtime=False)
    traces = (eng.tick_traces, eng.prefill_traces)
    assert traces[0] == 1
    eng._prm = rt2.jit_prm  # same treedef/avals, different weights
    c2, _ = eng.run([dataclasses.replace(req)], realtime=False)
    assert (eng.tick_traces, eng.prefill_traces) == traces, \
        "swapping the param ARGUMENT must not retrace anything"
    assert c1[0].tokens != c2[0].tokens, \
        "greedy streams ignored the swapped weights — params are baked in"
    # and the swapped-in tree drives the engine to rt2's own oracle stream
    out2, _ = drive_session(rt2, jnp.asarray(req.prompt)[None], cfg.vocab,
                            gen=req.max_tokens, temperature=0.0, top_k=0,
                            seed=req.seed)
    assert c2[0].tokens == out2[0].tolist()
