"""Training substrate: optimizer, compression, checkpointing, fault
tolerance, elasticity, data pipeline statelessness."""
import json
import os
import signal
import tempfile
import threading
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, not error, when absent
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.quantize import QuantSpec
from repro.core.qlinear import leaf_alpha
from repro.data.synth import token_stream
from repro.data.text import ByteCorpus
from repro.models import transformer as T
from repro.train import checkpoint as CK
from repro.train import compress as C
from repro.train.elastic import best_mesh_shape
from repro.train.fault_tolerance import PreemptionHandler, StragglerMonitor
from repro.train.optimizer import (OptConfig, PlateauLR, clip_by_global_norm,
                                   opt_init, opt_update, schedule)
from repro.train.train_step import make_train_step, train_state_init

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


# --- optimizer ---------------------------------------------------------------

def test_adamw_reduces_quadratic():
    cfg = OptConfig(kind="adamw", lr=0.1)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt_init(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt_update(grads, state, params, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_schedule_warmup_cosine():
    cfg = OptConfig(lr=1.0, warmup_steps=10, decay_steps=100, min_lr_frac=0.1)
    assert float(schedule(jnp.asarray(0), cfg)) == pytest.approx(0.1)
    assert float(schedule(jnp.asarray(9), cfg)) == pytest.approx(1.0)
    assert float(schedule(jnp.asarray(1000), cfg)) == pytest.approx(0.1)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-4)


def test_plateau_lr_quarters_on_rise():
    p = PlateauLR()
    assert p.update(100.0) == 1.0
    assert p.update(90.0) == 1.0
    assert p.update(95.0) == 0.25      # paper: divide by 4 on val increase


def test_quantized_train_keeps_masters_in_range():
    cfg = get_config("qwen3-0.6b").reduced()
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    opt = OptConfig(lr=5e-3)
    state = train_state_init(params, opt, jax.random.PRNGKey(1))
    step = jax.jit(make_train_step(cfg, opt))
    for i in range(5):
        b = {k: jnp.asarray(v) for k, v in
             token_stream(i, 4, 16, cfg.vocab).items()}
        state, _ = step(state, b)
    lp = state.params["stack"][0]
    w = lp["attn"]["Wq"]
    a = leaf_alpha(w.shape)
    assert float(jnp.max(jnp.abs(w))) <= a + 1e-6


# --- gradient compression ----------------------------------------------------

@given(st.integers(0, 2**31 - 1))
def test_ternary_compress_support_and_scale(seed):
    g = jax.random.normal(jax.random.PRNGKey(seed), (32,)) * 0.1
    t, scale = C.ternary_compress(g, jax.random.PRNGKey(seed ^ 3))
    lv = np.unique(np.round(np.asarray(t / scale), 5))
    assert set(lv).issubset({-1.0, 0.0, 1.0})


def test_ternary_compress_unbiased():
    g = jnp.array([0.05, -0.02, 0.0, 0.08])
    keys = jax.random.split(jax.random.PRNGKey(0), 6000)
    ts = jax.vmap(lambda k: C.ternary_compress(g, k)[0])(keys)
    np.testing.assert_allclose(np.asarray(jnp.mean(ts, 0)), np.asarray(g),
                               atol=6e-3)


def test_error_feedback_conserves_signal():
    """residual + emitted == corrected gradient, exactly."""
    g = {"w": jnp.array([0.03, -0.07, 0.01])}
    res = {"w": jnp.array([0.01, 0.0, -0.02])}
    out, new_res = C.compress_tree(g, jax.random.PRNGKey(0), res)
    np.testing.assert_allclose(
        np.asarray(out["w"] + new_res["w"]),
        np.asarray(g["w"] + res["w"]), rtol=1e-6)


def test_compressed_bytes_ratio():
    g = {"w": jnp.zeros((1024, 1024))}
    full, packed = C.compressed_bytes(g)
    assert full / packed > 15  # ~16x (2-bit codes + scale)


# --- checkpointing -----------------------------------------------------------

def _tiny_state():
    cfg = get_config("qwen3-0.6b").reduced()
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    return train_state_init(params, OptConfig(), jax.random.PRNGKey(1)), cfg


def test_checkpoint_roundtrip_exact():
    state, _ = _tiny_state()
    with tempfile.TemporaryDirectory() as d:
        CK.save(state, d, 3)
        restored = CK.restore(state, d)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_and_gc():
    state, _ = _tiny_state()
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            CK.save(state, d, s, keep=2)
        kept = sorted(p.name for p in Path(d).glob("step_*"))
        assert kept == ["step_00000004", "step_00000005"]
        # a stale tmp dir (simulated crash) must be cleaned by the next save
        crash = Path(d) / "step_00000099.tmp-dead"
        crash.mkdir()
        CK.save(state, d, 6, keep=2)
        assert not crash.exists()
        assert CK.latest_step(d) == 6


def test_checkpoint_restore_rejects_shape_mismatch():
    state, _ = _tiny_state()
    with tempfile.TemporaryDirectory() as d:
        CK.save(state, d, 1)
        bad = state._replace(rng=jnp.zeros((7,), jnp.uint32))
        with pytest.raises(ValueError):
            CK.restore(bad, d, 1)


def test_async_checkpointer_overlaps_and_matches():
    state, _ = _tiny_state()
    with tempfile.TemporaryDirectory() as d:
        ck = CK.AsyncCheckpointer(d)
        ck.save_async(state, 10)
        ck.wait()
        restored = CK.restore(state, d, 10)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_is_sample_exact():
    """Stateless (step-indexed) data + checkpoint => identical trajectory."""
    cfg = get_config("qwen3-0.6b").reduced()
    opt = OptConfig(lr=1e-3)
    step = jax.jit(make_train_step(cfg, opt))

    def run(state, s0, s1):
        for i in range(s0, s1):
            b = {k: jnp.asarray(v) for k, v in
                 token_stream(i, 4, 16, cfg.vocab).items()}
            state, m = step(state, b)
        return state, float(m["loss"])

    params = T.model_init(jax.random.PRNGKey(0), cfg)
    st = train_state_init(params, opt, jax.random.PRNGKey(1))
    straight, loss_straight = run(st, 0, 6)

    st2 = train_state_init(params, opt, jax.random.PRNGKey(1))
    st2, _ = run(st2, 0, 3)
    with tempfile.TemporaryDirectory() as d:
        CK.save(st2, d, 3)
        resumed = CK.restore(st2, d, 3)
    resumed, loss_resumed = run(resumed, 3, 6)
    assert loss_resumed == pytest.approx(loss_straight, rel=1e-6)


# --- fault tolerance / elasticity --------------------------------------------

def test_preemption_handler_flag():
    h = PreemptionHandler(signals=())
    assert not h.preempted
    h.simulate()
    assert h.preempted


def test_straggler_monitor_flags_slow_host():
    m = StragglerMonitor(n_hosts=4, ratio=1.5, patience=2)
    flagged = []
    for _ in range(4):
        flagged = m.record_all({0: 1.0, 1: 1.0, 2: 1.05, 3: 2.5})
    assert flagged == [3]


def test_straggler_monitor_recovers():
    m = StragglerMonitor(n_hosts=2, ratio=1.5, patience=2)
    m.record_all({0: 1.0, 1: 3.0})
    m.record_all({0: 1.0, 1: 1.0})   # host recovers -> strikes reset
    for _ in range(3):
        out = m.record_all({0: 1.0, 1: 1.0})
    assert out == []


def test_best_mesh_shape_preserves_model_axis():
    plan = best_mesh_shape(256, want_model=16, global_batch=256)
    assert plan.shape == (16, 16) and plan.dropped_devices == 0
    assert 256 % plan.shape[0] == 0
    # lose a host (8 chips): keep model=16, shrink data, rescale batch
    plan = best_mesh_shape(248, want_model=16, global_batch=256)
    assert plan.shape[-1] == 16 and plan.dropped_devices < 16
    assert plan.shape[0] == 15 and plan.per_replica_batch == 17


def test_best_mesh_multi_pod():
    plan = best_mesh_shape(512, want_model=16, global_batch=256, pods=2)
    assert plan.shape == (2, 16, 16)
    assert plan.per_replica_batch * 2 * 16 == 256


# --- data pipeline -----------------------------------------------------------

def test_corpus_batches_deterministic_and_disjoint_hosts():
    corpus = ByteCorpus.from_bytes(bytes(range(97, 123)) * 400)
    b1 = corpus.batch("train", 7, 8, 16)
    b2 = corpus.batch("train", 7, 8, 16)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["targets"][:, :-1])
    h0 = corpus.batch("train", 7, 8, 16, host_id=0, n_hosts=2)
    h1 = corpus.batch("train", 7, 8, 16, host_id=1, n_hosts=2)
    assert h0["tokens"].shape[0] == 4
    np.testing.assert_array_equal(np.vstack([h0["tokens"], h1["tokens"]]),
                                  b1["tokens"])


def test_corpus_splits_do_not_overlap():
    corpus = ByteCorpus.from_bytes(b"x" * 1000)
    t, v, te = (corpus.splits[s] for s in ("train", "valid", "test"))
    assert t[1] <= v[0] and v[1] <= te[0] and te[1] == 1000


def test_prefetcher_orders_steps():
    from repro.data.loader import Prefetcher
    pf = Prefetcher(lambda s: {"x": np.full((2,), s)}, start_step=5, depth=2)
    got = [next(pf) for _ in range(3)]
    pf.close()
    assert [s for s, _ in got] == [5, 6, 7]
    assert float(got[0][1]["x"][0]) == 5.0


def test_compressed_dp_train_step():
    """Ternary-compressed data-parallel gradients (shard_map path): step
    runs, loss finite, error-feedback residual updates."""
    from repro.runtime import use_mesh
    mesh = jax.make_mesh((1,), ("data",))
    cfg = get_config("qwen3-0.6b").reduced()
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    opt = OptConfig(lr=1e-3)
    st = train_state_init(params, opt, jax.random.PRNGKey(1), compress=True)
    assert st.residual is not None
    step = jax.jit(make_train_step(cfg, opt, mesh=mesh, compress_grads=True))
    with use_mesh(mesh):
        b = {k: jnp.asarray(v) for k, v in
             token_stream(0, 4, 16, cfg.vocab).items()}
        st2, m = step(st, b)
    assert np.isfinite(float(m["loss"]))
    # residual picked up the quantization error somewhere
    delta = sum(float(jnp.sum(jnp.abs(a))) for a in jax.tree.leaves(st2.residual))
    assert delta > 0.0
