"""MoE routing invariants + dense-oracle equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe as MOE


def _cfg(e=4, k=2, d=16, ff=32):
    base = get_config("mixtral-8x7b").reduced()
    return dataclasses.replace(base, n_experts=e, topk=k, d_model=d, d_ff=ff)


def test_route_respects_topk_and_capacity():
    cfg = _cfg(e=4, k=2)
    T, cap = 64, 8
    logits = jax.random.normal(jax.random.PRNGKey(0), (T, 4))
    disp, comb, aux = MOE.route(logits, cfg, cap)
    d = np.asarray(disp)
    assert d.shape == (T, 4, cap)
    # each (expert, slot) holds at most one token
    assert (d.sum(axis=0) <= 1.0 + 1e-6).all()
    # each token dispatched to <= topk slots
    assert (d.sum(axis=(1, 2)) <= cfg.topk + 1e-6).all()
    # combine weights nonzero only where dispatched
    c = np.asarray(comb)
    assert ((c > 0) <= (d > 0)).all()
    assert np.isfinite(float(aux))


def test_no_drop_moe_matches_dense_oracle():
    """With capacity = T the einsum-dispatch MoE must equal the obvious
    per-token loop over selected experts."""
    cfg = _cfg(e=4, k=2, d=8, ff=16)
    p = MOE.moe_init(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 6, cfg.d_model)) * 0.5
    y, _ = MOE.moe_apply(p, x, cfg, no_drop=True)

    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ p["router"]
    gates, idx = jax.lax.top_k(logits, cfg.topk)
    gates = jax.nn.softmax(gates, axis=-1)
    expect = np.zeros_like(np.asarray(xt))
    for t in range(xt.shape[0]):
        for j in range(cfg.topk):
            e = int(idx[t, j])
            h = jax.nn.silu(xt[t] @ p["Wgate"][e]) * (xt[t] @ p["Wup"][e])
            expect[t] += float(gates[t, j]) * np.asarray(h @ p["Wdown"][e])
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)), expect,
                               rtol=2e-4, atol=2e-4)


def test_capacity_drops_are_deterministic_and_bounded():
    cfg = _cfg(e=2, k=1)
    Tg = 32
    cap = MOE.capacity(Tg, cfg)
    assert cap >= cfg.topk
    # all tokens to one expert: only `cap` survive
    logits = jnp.stack([jnp.ones((Tg,)), jnp.zeros((Tg,))], axis=1)
    disp, comb, _ = MOE.route(logits, cfg, cap)
    assert float(disp[:, 0].sum()) == cap


def test_capacity_alignment_at_scale():
    cfg = _cfg(e=4, k=2)
    c = MOE.capacity(4096, cfg, align=128)
    assert c % 128 == 0


def test_grouped_equals_single_group():
    """Grouping changes capacity accounting only; with ample capacity the
    result must match the single-group computation."""
    cfg = dataclasses.replace(_cfg(e=4, k=2, d=8, ff=16), capacity_factor=4.0)
    p = MOE.moe_init(jax.random.PRNGKey(3), cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (4, 8, cfg.d_model)) * 0.5
    y1, _ = MOE.moe_apply(p, x, cfg, group_size=8)
    y2, _ = MOE.moe_apply(p, x, cfg, group_size=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)


def test_aux_loss_balanced_routing_is_lower():
    cfg = _cfg(e=4, k=1)
    T = 128
    balanced = jnp.tile(jnp.eye(4), (T // 4, 1)) * 5.0
    skewed = jnp.zeros((T, 4)).at[:, 0].set(5.0)
    _, _, aux_b = MOE.route(balanced, cfg, cap=T)
    _, _, aux_s = MOE.route(skewed, cfg, cap=T)
    assert float(aux_b) < float(aux_s)
