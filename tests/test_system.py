"""End-to-end behaviour of the whole system: quantized training improves a
real (synthetic-corpus) LM, the launcher round-trips through preemption, and
the roofline/analysis plumbing is self-consistent."""
import json
import subprocess
import sys
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.shapes import SHAPES, ShapeSpec
from repro.configs import get_config
from repro.launch.roofline import (Roofline, analytic_hbm_bytes,
                                   collective_wire_bytes, model_flops,
                                   param_counts)

REPO = Path(__file__).resolve().parents[1]


def test_train_launcher_end_to_end():
    """The public CLI trains a reduced arch on synthetic data and the loss
    decreases (example app (b) requirement exercised in CI)."""
    from repro.launch.train import main
    state = main(["--arch", "qwen3-0.6b", "--reduced", "--steps", "12",
                  "--batch", "4", "--seq", "32", "--log-every", "6"])
    assert state is not None


def test_train_launcher_resume_roundtrip(tmp_path):
    from repro.launch.train import main
    args = ["--arch", "qwen3-0.6b", "--reduced", "--batch", "4", "--seq",
            "16", "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
            "--log-every", "100"]
    main(args + ["--steps", "6"])
    # second run resumes from step 6's checkpoint and continues
    main(args + ["--steps", "10", "--resume", "auto"])
    from repro.train import checkpoint as CK
    assert CK.latest_step(tmp_path) == 10


def test_quantized_beats_random_on_structured_corpus():
    """Ternary model learns a Markov corpus well below uniform entropy."""
    from repro.core import bnlstm as BL
    from repro.core.quantize import QuantSpec
    from repro.data.synth import markov_bytes
    from repro.data.text import ByteCorpus
    from repro.train.optimizer import OptConfig
    from repro.train.train_step import (make_rnn_train_step, make_rnn_eval,
                                        train_state_init)

    data = markov_bytes(40_000, vocab=32, seed=0)
    corpus = ByteCorpus.from_bytes(bytes(bytearray(np.asarray(data) % 256)))
    cfg = BL.RNNConfig(vocab=corpus.vocab, d_hidden=64,
                       quant=QuantSpec(mode="ternary", norm="batch"))
    var = BL.rnn_lm_init(jax.random.PRNGKey(0), cfg)
    st = train_state_init(var["params"], OptConfig(lr=5e-3),
                          jax.random.PRNGKey(1), bn_state=var["state"])
    step = jax.jit(make_rnn_train_step(cfg, OptConfig(lr=5e-3)))
    for i in range(60):
        b = {k: jnp.asarray(v) for k, v in
             corpus.batch("train", i, 16, 32).items()}
        st, m = step(st, b)
    ev = jax.jit(make_rnn_eval(cfg))
    b = {k: jnp.asarray(v) for k, v in corpus.batch("valid", 0, 16, 32).items()}
    bpc = float(ev(st, b)["bpc"])
    uniform = np.log2(corpus.vocab)
    assert bpc < uniform * 0.8, f"bpc {bpc} vs uniform {uniform}"


# --- roofline plumbing -------------------------------------------------------

def test_collective_parser_on_synthetic_hlo():
    hlo = """
  %all-reduce.1 = f32[128,1024]{1,0} all-reduce(%dot), replica_groups=[2,4]<=[8]
  %ag = bf16[64,64]{1,0} all-gather(%x), replica_groups=[16,16]<=[256]
  %all-reduce-done.1 = f32[128,1024]{1,0} all-reduce-done(%ar)
"""
    out = collective_wire_bytes(hlo)
    assert out["all-reduce"] == pytest.approx(2 * 128 * 1024 * 4 * 3 / 4)
    assert out["all-gather"] == pytest.approx(64 * 64 * 2 * 15 / 16)


def test_param_counts_sane():
    total, active = param_counts(get_config("llama3-8b"))
    assert 7.5e9 < total < 9e9 and total == active
    total, active = param_counts(get_config("mixtral-8x7b"))
    assert 44e9 < total < 50e9 and 11e9 < active < 15e9
    total, active = param_counts(get_config("qwen3-moe-30b-a3b"))
    assert 28e9 < total < 33e9 and 2.5e9 < active < 4.5e9
    total, active = param_counts(get_config("llama-3.2-vision-90b"))
    assert 80e9 < total < 100e9


def test_model_flops_conventions():
    cfg = get_config("llama3-8b")
    tr = model_flops(cfg, SHAPES["train_4k"], 256)
    de = model_flops(cfg, SHAPES["decode_32k"], 256)
    _, active = param_counts(cfg)
    assert tr == pytest.approx(6 * active * 256 * 4096 / 256)
    assert de == pytest.approx(2 * active * 128 / 256)


def test_analytic_memory_packed_weights_shrink_decode():
    """The paper's claim, translated: packed 2-bit weights cut decode HBM
    traffic (weight stream) ~16x vs bf16 when weights dominate."""
    cfg = get_config("qwen3-1.7b")
    sh = ShapeSpec("decode_small", 1024, 1, "decode")
    full = analytic_hbm_bytes(cfg, sh, 1, weight_bits=16)
    packed = analytic_hbm_bytes(cfg, sh, 1, weight_bits=2)
    assert full / packed > 5  # weight-dominated at short context / batch 1


def test_roofline_dataclass_terms():
    r = Roofline(flops=197e12, hbm_bytes=819e9, wire_bytes=25e9,
                 collectives={"all-gather": 25e9}, model_flops=98.5e12)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(1.0)
    assert r.t_collective == pytest.approx(0.5)
    assert r.dominant in ("compute", "memory")
    assert r.useful_flop_ratio == pytest.approx(0.5)


def test_dryrun_results_if_present():
    """Validate any dry-run cells already produced by the sweep."""
    outdir = REPO / "results" / "dryrun"
    if not outdir.exists():
        pytest.skip("no dry-run results yet")
    cells = [json.loads(p.read_text()) for p in outdir.glob("*.json")]
    if not cells:
        pytest.skip("no cells yet")
    for c in cells:
        assert c["status"] in ("ok", "skipped", "error")
        if c["status"] == "ok":
            assert c["flops"] > 0
            assert c["roofline"]["dominant"] in ("compute", "memory",
                                                 "collective")
