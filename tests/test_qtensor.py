"""QTensor + export pipeline: the one quantized-weight API train -> serving.

Covers: pack -> qmatmul -> unpack parity vs fp matmul (binary & ternary,
including K not a multiple of the kernel block so the ops.py padding path is
exercised), pytree/jit round-trips, the explicit QuantPolicy, export_packed
for both model families, real-vs-analytic packed bytes, and checkpointing
packed trees.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import bnlstm as BL
from repro.core import quantize as Q
from repro.core.qlinear import is_quantizable, quantize_tree
from repro.core.qtensor import (QTensor, analytic_nbytes, export_packed,
                                is_qtensor, tree_nbytes)
from repro.core.quantize import QuantPolicy, QuantSpec
from repro.kernels.ops import qmatmul
from repro.models import transformer as T


# --- pack -> qmatmul -> unpack parity ---------------------------------------


@pytest.mark.parametrize("mode", ["ternary", "binary"])
@pytest.mark.parametrize("K", [8, 67, 100, 256])
def test_qmatmul_matches_fp_matmul(mode, K):
    """K=67/100 are multiples of neither the pack group nor the kernel block:
    the zero-pad path in ops.py must contribute exactly nothing."""
    w = jax.random.normal(jax.random.PRNGKey(K), (K, 40)) * 0.05
    qt = QTensor.from_master(w, mode)
    x = jax.random.normal(jax.random.PRNGKey(K + 1), (2, 3, K))
    y = qmatmul(x, qt)
    assert y.shape == (2, 3, 40)
    assert y.dtype == x.dtype
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ qt.dequantize()),
                               rtol=1e-4, atol=1e-4)
    # and dequantize itself equals the paper's deterministic quantizer
    det = (Q.ternarize_deterministic if mode == "ternary"
           else Q.binarize_deterministic)(w, qt.alpha)
    np.testing.assert_allclose(np.asarray(qt.dequantize()), np.asarray(det),
                               atol=1e-6)


def test_qmatmul_fp_passthrough_and_mismatch():
    w = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
    np.testing.assert_allclose(np.asarray(qmatmul(x, w)), np.asarray(x @ w))
    qt = QTensor.from_master(w, "ternary")
    with pytest.raises(ValueError, match="mismatch"):
        qmatmul(jnp.ones((4, 17)), qt)


def test_qmatmul_stacked_per_matrix():
    """Stacked (experts / scan layers) QTensors apply per matrix."""
    ws = jax.random.normal(jax.random.PRNGKey(2), (3, 67, 24)) * 0.05
    qs = QTensor.from_master(ws, "ternary")
    xs = jax.random.normal(jax.random.PRNGKey(3), (3, 5, 67))
    y = qmatmul(xs, qs)
    ref = jnp.einsum("lbk,lkn->lbn", xs, qs.dequantize())
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_qtensor_channel_scale():
    w = jax.random.normal(jax.random.PRNGKey(4), (32, 16)) * 0.05
    s = jnp.linspace(0.5, 2.0, 16)
    qt = QTensor.from_master(w, "ternary", scale=s)
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 32))
    base = QTensor.from_master(w, "ternary")
    np.testing.assert_allclose(np.asarray(qmatmul(x, qt)),
                               np.asarray(qmatmul(x, base) * s),
                               rtol=1e-5, atol=1e-5)


# --- pytree behavior --------------------------------------------------------


def test_qtensor_tree_flatten_jit_roundtrip():
    w = jax.random.normal(jax.random.PRNGKey(6), (67, 40)) * 0.05
    qt = QTensor.from_master(w, "ternary")
    leaves, treedef = jax.tree_util.tree_flatten(qt)
    assert all(l.dtype == jnp.uint32 for l in leaves)  # codes only, no fp
    qt2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert (qt2.k, qt2.mode, qt2.alpha) == (qt.k, qt.mode, qt.alpha)

    # QTensor crosses jit boundaries as an argument pytree
    f = jax.jit(lambda q, x: qmatmul(x, q))
    x = jax.random.normal(jax.random.PRNGKey(7), (4, 67))
    np.testing.assert_allclose(np.asarray(f(qt, x)), np.asarray(qmatmul(x, qt)),
                               rtol=1e-5, atol=1e-5)

    # stacked QTensors slice and scan like the fp tree they replace
    ws = jax.random.normal(jax.random.PRNGKey(8), (3, 32, 16)) * 0.05
    qs = QTensor.from_master(ws, "binary")
    sl = jax.tree.map(lambda l: l[1], qs)
    assert isinstance(sl, QTensor) and sl.shape == (32, 16) and sl.k == 32
    xs = jnp.ones((2, 32))
    _, ys = jax.lax.scan(lambda c, q: (c, qmatmul(xs, q)), 0.0, qs)
    assert ys.shape == (3, 2, 16)


# --- QuantPolicy ------------------------------------------------------------


def test_quant_policy_explicit_gating():
    pol = QuantSpec(mode="ternary").policy()
    assert pol.matches_name("Wq") and pol.matches_name("Wdown")
    for name in ("embed", "head", "router", "norm1", "sq", "bn_x", "wA"):
        assert not pol.matches_name(name)
    # min_ndim: a 1-D leaf named like a weight still never quantizes
    assert not pol.matches_name("Wq", ndim=1)

    # quantize_embeddings routes through the policy's extra names
    pol2 = QuantSpec(mode="ternary", quantize_embeddings=True).policy()
    assert pol2.matches_name("head") and pol2.matches_name("embed")

    # exclude beats include; custom include patterns work (BN-LSTM names)
    pol3 = QuantPolicy(include=("wx", "wh"), exclude=("wx",))
    assert pol3.matches_name("wh") and not pol3.matches_name("wx")

    # path-qualified patterns gate by subtree
    pol4 = QuantPolicy(include=("W*",), exclude=("enc/*",))
    assert not pol4.matches_name("Wq", path_str="enc/stack/Wq")
    assert pol4.matches_name("Wq", path_str="stack/Wq")

    # the legacy name-only helper agrees with the default policy
    assert is_quantizable("Wq") and not is_quantizable("embed")


def test_quantize_tree_honors_policy_exclude():
    spec = QuantSpec(mode="ternary", stochastic=False, exclude=("Wb",))
    params = {"Wa": jnp.full((32, 8), 0.3), "Wb": jnp.full((32, 8), 0.3),
              "bias": jnp.zeros((8,))}
    out = quantize_tree(params, spec, None)
    a = Q.leaf_alpha((32, 8))
    vals = np.unique(np.asarray(out["Wa"]))
    assert all(np.isclose(v, (-a, 0.0, a), atol=1e-6).any() for v in vals)
    np.testing.assert_array_equal(np.asarray(out["Wb"]),
                                  np.asarray(params["Wb"]))


# --- export pipeline --------------------------------------------------------


def _packed_leaves(tree):
    return [l for l in jax.tree_util.tree_leaves(tree, is_leaf=is_qtensor)
            if is_qtensor(l)]


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mixtral-8x7b"])
def test_export_packed_transformer_serve_parity(arch):
    """prefill/decode against the exported packed tree == the fp
    deterministic-quantization serving path (dense + MoE families)."""
    cfg = get_config(arch).reduced()
    cfg = cfg.with_quant(QuantSpec(mode="ternary", norm="channel"))
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    qparams = export_packed(params, cfg.quant)
    assert len(_packed_leaves(qparams)) > 0

    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    mk = lambda: T.init_caches(cfg, B, S + 4, dtype=jnp.float32)

    c_fp, c_q = mk(), mk()
    lg_fp, c_fp = T.prefill(params, tokens, c_fp, cfg)
    lg_q, c_q = T.prefill(qparams, tokens, c_q, cfg)
    np.testing.assert_allclose(np.asarray(lg_q), np.asarray(lg_fp),
                               rtol=2e-3, atol=2e-3)

    nxt = jnp.argmax(lg_fp[:, : cfg.vocab], axis=-1).astype(jnp.int32)
    d_fp, _ = T.decode_step(params, nxt, c_fp, cfg)
    d_q, _ = T.decode_step(qparams, nxt, c_q, cfg)
    np.testing.assert_allclose(np.asarray(d_q), np.asarray(d_fp),
                               rtol=2e-3, atol=2e-3)


def test_export_packed_rnn_parity():
    cfg = BL.RNNConfig(vocab=70, d_hidden=48,  # 70, 192: K % group != 0 paths
                       quant=QuantSpec(mode="ternary", norm="batch"))
    var = BL.rnn_lm_init(jax.random.PRNGKey(0), cfg)
    qparams = BL.export_packed_rnn(var["params"], cfg)
    assert len(_packed_leaves(qparams)) == 2
    assert not is_qtensor(qparams["head"]["ws"])  # classifier stays fp

    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    lg_fp = BL.rnn_lm_apply(var, tokens, cfg, training=False)
    lg_q = BL.rnn_lm_apply({"params": qparams, "state": var["state"]},
                           tokens, cfg, training=False)
    np.testing.assert_allclose(np.asarray(lg_q), np.asarray(lg_fp),
                               rtol=2e-4, atol=2e-4)


def test_rnn_mixed_packed_tree_rejected():
    """A half-exported layer must fail loudly, not serve a raw fp master."""
    cfg = BL.RNNConfig(vocab=64, d_hidden=32,
                       quant=QuantSpec(mode="ternary", norm="batch"))
    var = BL.rnn_lm_init(jax.random.PRNGKey(0), cfg)
    mixed = export_packed(var["params"], cfg.quant,
                          policy=QuantPolicy(include=("wh",)))
    tokens = jnp.zeros((1, 4), jnp.int32)
    with pytest.raises(ValueError, match="mixed packed/fp"):
        BL.rnn_lm_apply({"params": mixed, "state": var["state"]},
                        tokens, cfg, training=False)


def test_packed_bytes_real_equals_analytic():
    """The serving footprint is measured, and the measurement matches the
    per-matrix analytic size (launch/serve.py prints the measured one)."""
    from repro.launch.serve import packed_model_bytes

    cfg = get_config("qwen3-1.7b").reduced()
    cfg = cfg.with_quant(QuantSpec(mode="ternary"))
    params = T.model_init(jax.random.PRNGKey(0), cfg)
    qparams = export_packed(params, cfg.quant)

    real = sum(l.nbytes for l in _packed_leaves(qparams))
    analytic = sum(analytic_nbytes(l.shape, l.mode)
                   for l in _packed_leaves(qparams))
    assert real == analytic

    fp_all, packed_all = packed_model_bytes(qparams)
    fp_leaves = sum(l.size * l.dtype.itemsize
                    for l in jax.tree_util.tree_leaves(qparams,
                                                       is_leaf=is_qtensor)
                    if not is_qtensor(l))
    assert packed_all == real + fp_leaves
    assert fp_all > packed_all  # the whole point


def test_checkpoint_roundtrip_packed_tree(tmp_path):
    from repro.train import checkpoint as CK

    cfg = BL.RNNConfig(vocab=64, d_hidden=32,
                       quant=QuantSpec(mode="ternary", norm="batch"))
    var = BL.rnn_lm_init(jax.random.PRNGKey(0), cfg)
    qparams = BL.export_packed_rnn(var["params"], cfg)
    CK.save(qparams, tmp_path, step=7)

    template = BL.export_packed_rnn(
        BL.rnn_lm_init(jax.random.PRNGKey(1), cfg)["params"], cfg)
    restored = CK.restore(template, tmp_path)
    for got, want in zip(_packed_leaves(restored), _packed_leaves(qparams)):
        np.testing.assert_array_equal(np.asarray(got.codes),
                                      np.asarray(want.codes))
        assert (got.k, got.mode) == (want.k, want.mode)

    # metadata validation: restoring into a differently-packed template fails
    bad_cfg = dataclasses.replace(cfg, quant=QuantSpec(mode="binary"))
    bad = BL.export_packed_rnn(
        BL.rnn_lm_init(jax.random.PRNGKey(1), bad_cfg)["params"], bad_cfg)
    with pytest.raises(ValueError, match="QTensor"):
        CK.restore(bad, tmp_path)
