"""The paper's faithful reproduction path: BN-LSTM/GRU with learned
binary/ternary recurrent weights (Algorithm 1 / Eq. 7)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bnlstm as BL
from repro.core import quantize as Q
from repro.core.recurrent_bn import bn_apply, bn_init
from repro.core.quantize import QuantSpec
from repro.data.synth import markov_bytes
from repro.data.text import ByteCorpus
from repro.train.optimizer import OptConfig
from repro.train.train_step import make_rnn_train_step, make_rnn_eval, train_state_init

# a small structured corpus (order-2 Markov) — something to actually learn
_CORPUS = ByteCorpus.from_bytes(
    bytes(bytearray(np.asarray(markov_bytes(30_000, vocab=24, seed=3)) % 256)))


def _cfg(mode="ternary", cell="lstm", hidden=48):
    return BL.RNNConfig(vocab=_CORPUS.vocab, d_hidden=hidden, cell=cell,
                        quant=QuantSpec(mode=mode, norm="batch"))


def _train(cfg, steps=30, seed=0, lr=5e-3):
    var = BL.rnn_lm_init(jax.random.PRNGKey(seed), cfg)
    st = train_state_init(var["params"], OptConfig(lr=lr),
                          jax.random.PRNGKey(seed + 1), bn_state=var["state"])
    step = jax.jit(make_rnn_train_step(cfg, OptConfig(lr=lr)))
    losses = []
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in
             _CORPUS.batch("train", i, 16, 24).items()}
        st, m = step(st, b)
        losses.append(float(m["loss"]))
    return st, losses


@pytest.mark.parametrize("mode", ["ternary", "binary"])
@pytest.mark.parametrize("cell", ["lstm", "gru"])
def test_quantized_rnn_trains(mode, cell):
    st, losses = _train(_cfg(mode, cell))
    assert losses[-1] < losses[0]          # learning happens
    assert np.isfinite(losses).all()


def test_master_weights_stay_clipped():
    cfg = _cfg("ternary")
    st, _ = _train(cfg, steps=10)
    for lp in st.params["layers"]:
        for name in ("wx", "wh"):
            a = Q.glorot_alpha(*lp[name].shape)
            assert float(jnp.max(jnp.abs(lp[name]))) <= a + 1e-6


def test_inference_uses_pure_ternary_weights():
    """Paper §5.5: the trained model can ONLY use quantized weights at
    inference; deterministic eval puts every recurrent weight in {-a,0,a}."""
    cfg = _cfg("ternary")
    st, _ = _train(cfg, steps=5)
    lp = st.params["layers"][0]
    a = Q.glorot_alpha(*lp["wh"].shape)
    qh = Q.ternarize_deterministic(lp["wh"], a)
    assert set(np.round(np.unique(np.asarray(qh) / a), 6)).issubset({-1.0, 0.0, 1.0})


def test_eval_mode_uses_running_stats_and_is_deterministic():
    cfg = _cfg("ternary")
    st, _ = _train(cfg, steps=5)
    ev = jax.jit(make_rnn_eval(cfg))
    b = {k: jnp.asarray(v) for k, v in
         _CORPUS.batch("valid", 0, 8, 16).items()}
    m1, m2 = ev(st, b), ev(st, b)
    assert float(m1["loss"]) == float(m2["loss"])


def test_bn_transform_matches_eq3():
    """BN(x; phi, gamma) = gamma + phi * (x - E x)/sqrt(V x + eps)."""
    p, s = bn_init(4, phi_init=0.3, gamma_init=0.1)
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 4)) * 3 + 1
    y, s2 = bn_apply(x, p, s, training=True)
    np.testing.assert_allclose(np.asarray(jnp.mean(y, 0)), 0.1, atol=1e-3)
    np.testing.assert_allclose(np.asarray(jnp.std(y, 0)), 0.3, atol=1e-2)
    assert float(s2.count) == 1.0


def test_bn_running_stats_converge_to_batch_stats():
    p, s = bn_init(3)
    x = jax.random.normal(jax.random.PRNGKey(1), (256, 3)) * 2 + 5
    for _ in range(300):
        _, s = bn_apply(x, p, s, training=True, momentum=0.95)
    np.testing.assert_allclose(np.asarray(s.mean), np.asarray(jnp.mean(x, 0)),
                               rtol=1e-2)
    np.testing.assert_allclose(np.asarray(s.var), np.asarray(jnp.var(x, 0)),
                               rtol=2e-2)


def test_binaryconnect_baseline_is_worse():
    """The paper's central negative result (Table 1): BinaryConnect (no BN,
    loss-unaware) underperforms the proposed BN-quantized training."""
    ours, ours_losses = _train(_cfg("ternary"), steps=40, lr=5e-3)
    bc_cfg = dataclasses.replace(
        _cfg("binaryconnect"), cell_norm=False)
    bc, bc_losses = _train(bc_cfg, steps=40, lr=5e-3)
    assert ours_losses[-1] < bc_losses[-1] + 0.5  # ours at least comparable
    # and ours must actually be learning the sequence structure
    assert ours_losses[-1] < ours_losses[0] * 0.98


def test_memory_sizes_match_table1():
    """Paper Table 1 'Size' column: PTB char model (LSTM 1000) weights are
    16.8 MB fp32 -> 525 KB binary -> 1050 KB ternary."""
    from repro.configs.rnn_paper import char_ptb
    cfg = char_ptb()
    d_in, h = cfg.vocab, cfg.d_hidden
    n_weights = (d_in * 4 * h) + (h * 4 * h)
    # paper's KByte = 1000 bytes; with vocab 50 the numbers land exactly
    assert n_weights * 4 / 1000 == pytest.approx(16800, rel=0.01)
    assert n_weights / 8 / 1000 == pytest.approx(525, rel=0.01)    # binary
    assert n_weights / 4 / 1000 == pytest.approx(1050, rel=0.01)   # ternary
