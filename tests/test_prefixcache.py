"""Prefix-state cache (DESIGN.md §10): a spliced prefix must be INVISIBLE.

A request whose prompt prefix is served from the cache — one state-row
splice instead of re-prefilling — must stream bytes identical to the same
request cold, which (by the §8 chunked-prefill contract) is identical to
the sequential oracle.  Proven for the paper's LSTM (packed ternary: the
snapshot is two (L, H) rows) and for an attention arch (qwen3: narrowed kv
columns, zero-widened at splice).  Plus the cache's own guarantees: LRU
eviction under the byte budget, a poisoned-prefix guard (digest match with
different stored ids is a collision, never a hit), and one-trace splicing.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import bnlstm as BL
from repro.core.quantize import QuantSpec
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine
from repro.serve.kvcache import (cache_init, cache_narrow, cache_update,
                                 cache_widen)
from repro.serve.prefixcache import PrefixCache, tree_bytes
from repro.serve.recurrent import (RNNRuntime, TransformerRuntime,
                                   drive_session, speculative_draft)

CTX = 48
_RUNTIMES: dict = {}


def _runtime(family):
    if family not in _RUNTIMES:
        if family.startswith("lstm"):
            packed = family == "lstm-packed"
            spec = (QuantSpec(mode="ternary", norm="batch") if packed
                    else QuantSpec(mode="none"))
            cfg = BL.RNNConfig(vocab=24, d_hidden=48, n_layers=2,
                               cell="lstm", quant=spec)
            var = BL.rnn_lm_init(jax.random.PRNGKey(0), cfg)
            params = var["params"]
            if packed:
                params = BL.export_packed_rnn(params, cfg)
            rt = RNNRuntime(cfg, {"params": params, "state": var["state"]})
            _RUNTIMES[family] = (rt, cfg.vocab, None)
        else:
            cfg = get_config("qwen3-0.6b").reduced()
            params = T.model_init(jax.random.PRNGKey(0), cfg)
            rt = TransformerRuntime(cfg, params)
            _RUNTIMES[family] = (rt, cfg.vocab, CTX)
    return _RUNTIMES[family]


def _expected(family, req):
    rt, vocab, ctx = _runtime(family)
    out, _ = drive_session(
        rt, jnp.asarray(req.prompt)[None], vocab, gen=req.max_tokens,
        temperature=req.temperature, top_k=req.top_k, seed=req.seed,
        context=ctx)
    return out[0].tolist()


# --- kv narrow/widen ---------------------------------------------------------


def test_cache_narrow_widen_roundtrip():
    sub = cache_init(1, 8, 2, 4, jnp.float32, per_slot=True)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 2, 4))
    sub = cache_update(sub, k, 2 * k)
    nar = cache_narrow(sub, 4)
    assert nar.k.shape == (1, 4, 2, 4) and nar.pos.tolist() == [4]
    wide = cache_widen(nar, sub.k.shape)
    np.testing.assert_array_equal(np.asarray(wide.k[:, :4]),
                                  np.asarray(sub.k[:, :4]))
    assert float(jnp.abs(wide.k[:, 4:]).max()) == 0.0  # zero tail: masked
    assert wide.pos.tolist() == [4]
    assert cache_widen(nar, nar.k.shape) is nar  # already full: no-op


def test_cache_narrow_rejects_ring():
    ring = cache_init(1, 8, 2, 4, jnp.float32, per_slot=True, ring=True)
    with pytest.raises(ValueError):
        cache_narrow(ring, 4)


# --- the cache data structure ------------------------------------------------


def _entry_state(nbytes):
    return np.zeros(nbytes, np.int8)


def test_lru_eviction_under_byte_budget():
    c = PrefixCache(100)
    c.bind(4)
    t = lambda i: np.full(4, i, np.int32)
    assert c.insert(t(1), _entry_state(40))
    assert c.insert(t(2), _entry_state(40))
    assert len(c) == 2 and c.bytes == 80
    c.lookup(np.concatenate([t(1), t(9)]))  # touch 1: now 2 is LRU
    assert c.insert(t(3), _entry_state(40))  # evicts 2, not 1
    s = c.stats()
    assert s["entries"] == 2 and s["bytes"] == 80 and s["evictions"] == 1
    assert c.lookup(np.concatenate([t(1), t(9)]))[0] == 4
    assert c.lookup(np.concatenate([t(2), t(9)]))[0] == 0  # evicted
    assert not c.insert(t(4), _entry_state(101))  # bigger than the budget
    assert c.stats()["entries"] == 2


def test_longest_boundary_prefix_wins_and_last_chunk_never_cached():
    c = PrefixCache(1 << 20)
    c.bind(4)
    p = np.arange(12, dtype=np.int32)
    c.insert(p[:4], _entry_state(8))
    c.insert(p[:8], _entry_state(8))
    assert c.lookup(p)[0] == 8       # longest wins, capped at size-1=11 -> 8
    assert c.lookup(p[:9])[0] == 8
    # a prompt that IS a cached boundary still re-runs its last chunk:
    # the cap is size-1, so only the 4-boundary is usable
    assert c.lookup(p[:8])[0] == 4
    assert c.lookup(p[:4])[0] == 0   # no boundary strictly inside 4 tokens
    assert c.bind(4) is None and len(c) == 2
    with pytest.raises(ValueError):
        c.bind(8)  # engines sharing a cache must agree on boundaries


def test_poisoned_prefix_guard(monkeypatch):
    """A digest collision must NEVER splice foreign state: entries store
    the exact ids they hashed and a mismatch is rejected + counted."""
    c = PrefixCache(1 << 20)
    c.bind(4)
    monkeypatch.setattr(PrefixCache, "_key",
                        staticmethod(lambda tokens: "collide"))
    c.insert(np.arange(4, dtype=np.int32), _entry_state(8))
    c.insert(np.arange(4, dtype=np.int32) + 50, _entry_state(8))  # refresh-
    assert len(c) == 1                # by-key: everything hashes together
    p, e = c.lookup(np.array([9, 9, 9, 9, 1], np.int32))
    assert (p, e) == (0, None), "id mismatch at a matching digest hit!"
    assert c.stats()["collisions"] >= 1


# --- engine integration: hit == cold, bit-exactly ----------------------------


def _cached_engine(family, *, slots=2, chunk=4, budget=1 << 24, spec_k=0):
    rt, vocab, _ = _runtime(family)
    draft = speculative_draft(rt, mode="ternary") if spec_k else None
    return ServeEngine(rt, vocab, slots=slots, max_context=CTX,
                       prefill_chunk=chunk, prefix_cache=PrefixCache(budget),
                       draft=draft, spec_k=spec_k), vocab


@pytest.mark.parametrize("family", ["lstm-packed", "qwen3"])
def test_prefix_hit_resume_is_bit_exact(family):
    """Request 1 (cold) populates boundary snapshots; requests sharing its
    prefix splice instead of re-prefilling — and every stream matches the
    oracle bit for bit, hit or miss."""
    eng, vocab = _cached_engine(family)
    rng = np.random.default_rng(5)
    system = rng.integers(0, vocab, size=9).astype(np.int32)  # 2 boundaries
    mk = lambda tail, seed: Request(
        prompt=np.concatenate([system, tail]).astype(np.int32),
        max_tokens=6, temperature=0.8, top_k=5, seed=seed)
    cold = mk(rng.integers(0, vocab, size=3), 11)
    same = dataclasses.replace(cold)                      # identical prompt
    fork = mk(rng.integers(0, vocab, size=5), 13)         # shared system

    c1, _ = eng.run([dataclasses.replace(cold)], realtime=False)
    assert c1[0].cached_tokens == 0 and eng.prefix_cache.stats()["misses"] == 1
    ins = eng.prefix_cache.stats()["insertions"]
    assert ins >= 2  # the 4- and 8-boundaries of the 12-token prompt

    c2, m2 = eng.run([same], realtime=False)
    assert c2[0].cached_tokens == 8, "longest boundary prefix must splice"
    c3, _ = eng.run([fork], realtime=False)
    assert c3[0].cached_tokens == 8

    exp = _expected(family, cold)
    assert c1[0].tokens == exp, "cold stream diverged from oracle"
    assert c2[0].tokens == exp, "HIT stream != COLD stream"
    assert c3[0].tokens == _expected(family, fork)
    assert m2["splice_traces"] == 1 and eng.tick_traces == 1
    s = eng.prefix_cache.stats()
    assert s["hits"] == 2 and s["hit_tokens"] == 16 and s["collisions"] == 0


def test_prefix_hit_under_speculative_decoding():
    """Spec engines snapshot BOTH pools: a spliced prefix must leave the
    draft in lockstep, or acceptance (and at temp 0, correctness of the
    one-trace invariant checks) would silently degrade."""
    eng, vocab = _cached_engine("lstm-fp", spec_k=3)
    rng = np.random.default_rng(6)
    system = rng.integers(0, vocab, size=10).astype(np.int32)
    mk = lambda tail, seed: Request(
        prompt=np.concatenate([system, tail]).astype(np.int32),
        max_tokens=8, temperature=0.0, top_k=0, seed=seed)
    a = mk(rng.integers(0, vocab, size=2), 21)
    b = mk(rng.integers(0, vocab, size=4), 22)
    ca, _ = eng.run([a], realtime=False)
    cb, mb = eng.run([b], realtime=False)
    assert cb[0].cached_tokens == 8
    assert ca[0].tokens == _expected("lstm-fp", a)
    assert cb[0].tokens == _expected("lstm-fp", b)
    assert eng.spec_traces == 1 and mb["splice_traces"] == 1
    e = next(iter(eng.prefix_cache._entries.values()))
    assert e.draft_state is not None, "spec entries must carry the draft half"


def test_engine_eviction_keeps_streams_exact():
    """A budget that can only hold ~one boundary forces eviction churn mid-
    workload; evicted prefixes silently fall back to cold prefill and the
    bytes never change."""
    rt, vocab, _ = _runtime("lstm-packed")
    one = tree_bytes(rt.init_state(1, CTX, per_slot=True))
    eng, _ = _cached_engine("lstm-packed", budget=2 * one)
    rng = np.random.default_rng(7)
    reqs = [Request(prompt=rng.integers(0, vocab, size=9).astype(np.int32),
                    max_tokens=5, temperature=0.8, top_k=5, seed=30 + i)
            for i in range(4)]
    comps, m = eng.run([dataclasses.replace(r) for r in reqs],
                       realtime=False)
    s = eng.prefix_cache.stats()
    assert s["evictions"] >= 1 and s["bytes"] <= 2 * one
    for c, r in zip(sorted(comps, key=lambda c: c.rid), reqs):
        assert c.tokens == _expected("lstm-packed", r)
    assert eng.tick_traces == 1


def test_unsupported_runtime_is_refused():
    """'whole'-granularity runtimes have no exact chunk boundaries to key —
    the constructor must refuse rather than serve approximate state."""
    import types

    rt, vocab, _ = _runtime("lstm-packed")
    shim = types.SimpleNamespace(family=rt.family, extras=None,
                                 pad_buckets=False,
                                 chunk_granularity="whole")
    with pytest.raises(NotImplementedError):
        ServeEngine(shim, vocab, slots=1, max_context=CTX,
                    prefix_cache=PrefixCache(1 << 20))
