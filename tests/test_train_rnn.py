"""The paper's BN-LSTM training loop: plateau schedule semantics, SGD
momentum, bn_state/residual checkpoint round-trips, sample-exact resume,
and the compressed-DP shard_map path on the RNN step.

Deliberately free of optional deps (no hypothesis): these run in every
container tier-1 does.
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bnlstm as BL
from repro.core.quantize import QuantSpec
from repro.data.synth import token_stream
from repro.train import checkpoint as CK
from repro.train.optimizer import OptConfig, PlateauLR, opt_init, opt_update
from repro.train.train_step import make_rnn_train_step, train_state_init


# --- plateau schedule (paper word-PTB: /4 on val rise vs PREVIOUS eval) ------


def test_plateau_lr_recovery_does_not_collapse():
    """The comparison is vs the PREVIOUS eval, not the all-time best: a
    noisy recovery (falling again, but not yet below the old best) must not
    keep dividing — only a genuine new rise cuts the LR further."""
    p = PlateauLR()
    p.update(100.0)
    p.update(90.0)
    assert p.update(95.0) == 0.25      # rise vs previous -> /4
    assert p.update(93.0) == 0.25      # recovering: above best, below prev
    assert p.update(91.0) == 0.25      # still recovering
    assert p.update(92.0) == 0.0625    # a real second rise cuts again
    assert p.best == 90.0              # best tracked for reporting only


def test_plateau_replay_rebuilds_state():
    """Restart path: replaying the journaled eval curve reproduces the
    interrupted run's exact schedule state."""
    hist = [100.0, 90.0, 95.0, 93.0, 96.0]
    p = PlateauLR()
    for v in hist:
        p.update(v)
    q = PlateauLR()
    assert q.replay(hist) == p.scale
    assert (q.prev, q.best) == (p.prev, p.best)


# --- SGD momentum ------------------------------------------------------------


def test_sgd_momentum_honored():
    """OptConfig.momentum actually drives the SGD buffer (it was once a
    hardcoded 0.0): two constant-gradient steps must compound by 1+mu."""
    cfg = OptConfig(kind="sgd", lr=0.1, momentum=0.9)
    params = {"w": jnp.array([1.0])}
    g = {"w": jnp.array([1.0])}
    p1, s1, _ = opt_update(g, opt_init(params, cfg), params, cfg)
    assert float(p1["w"][0]) == pytest.approx(1.0 - 0.1)
    p2, _, _ = opt_update(g, s1, p1, cfg)
    assert float(p2["w"][0]) == pytest.approx(0.9 - 0.1 * 1.9)  # m2 = .9+1
    # plain SGD (the default) is unchanged: no buffer carry
    plain = OptConfig(kind="sgd", lr=0.1)
    q1, t1, _ = opt_update(g, opt_init(params, plain), params, plain)
    q2, _, _ = opt_update(g, t1, q1, plain)
    assert float(q2["w"][0]) == pytest.approx(1.0 - 2 * 0.1)


# --- bn_state/residual through checkpoint + resume ---------------------------


def _rnn_tiny(compress=False):
    cfg = BL.RNNConfig(vocab=24, d_hidden=32, cell="lstm",
                       quant=QuantSpec(mode="ternary", norm="batch"))
    var = BL.rnn_lm_init(jax.random.PRNGKey(0), cfg)
    opt = OptConfig(lr=1e-3)
    st = train_state_init(var["params"], opt, jax.random.PRNGKey(1),
                          bn_state=var["state"], compress=compress)
    return cfg, opt, st


def _rnn_batch(i, vocab):
    return {k: jnp.asarray(v) for k, v in token_stream(i, 4, 12, vocab).items()}


def test_rnn_checkpoint_roundtrip_bn_state_and_residual():
    """A TrainState carrying BN running statistics AND an error-feedback
    residual survives save/restore bit-exactly — including restoring into a
    template whose bn_state/residual are already populated."""
    from repro.runtime import use_mesh
    mesh = jax.make_mesh((1,), ("data",))
    cfg, opt, st = _rnn_tiny(compress=True)
    step = jax.jit(make_rnn_train_step(cfg, opt, mesh=mesh,
                                       compress_grads=True))
    with use_mesh(mesh):
        for i in range(2):
            st, _ = step(st, _rnn_batch(i, cfg.vocab))
    # the residual picked up quantization error; bn stats advanced
    assert sum(float(jnp.sum(jnp.abs(a)))
               for a in jax.tree.leaves(st.residual)) > 0
    with tempfile.TemporaryDirectory() as d:
        CK.save(st, d, 2)
        _, _, template = _rnn_tiny(compress=True)   # populated, different
        restored = CK.restore(template, d, 2)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_rnn_resume_is_sample_exact():
    """Interrupt-at-3 + restore == straight 6 steps, bn_state included."""
    cfg, opt, st0 = _rnn_tiny()
    step = jax.jit(make_rnn_train_step(cfg, opt))

    def run(state, s0, s1):
        for i in range(s0, s1):
            state, m = step(state, _rnn_batch(i, cfg.vocab))
        return state, float(m["loss"])

    straight, loss_straight = run(st0, 0, 6)
    _, _, st1 = _rnn_tiny()
    st1, _ = run(st1, 0, 3)
    with tempfile.TemporaryDirectory() as d:
        CK.save(st1, d, 3)
        _, _, template = _rnn_tiny()
        resumed = CK.restore(template, d, 3)
    resumed, loss_resumed = run(resumed, 3, 6)
    assert loss_resumed == pytest.approx(loss_straight, rel=1e-6)
    for a, b in zip(jax.tree.leaves(straight.bn_state),
                    jax.tree.leaves(resumed.bn_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_rnn_step_lr_scale_scales_lr():
    """The plateau schedule's host-side scale reaches the update as a traced
    scalar (same trace both calls — no retrace per scale change)."""
    cfg, opt, st = _rnn_tiny()
    step = jax.jit(make_rnn_train_step(cfg, opt))
    b = _rnn_batch(0, cfg.vocab)
    _, m1 = step(st, b, jnp.asarray(1.0, jnp.float32))
    _, m2 = step(st, b, jnp.asarray(0.25, jnp.float32))
    assert float(m2["lr"]) == pytest.approx(0.25 * float(m1["lr"]), rel=1e-6)


def test_rnn_compressed_dp_train_step():
    """make_rnn_train_step's shard_map compressed path: finite loss,
    residual update, BN running stats advance."""
    from repro.runtime import use_mesh
    mesh = jax.make_mesh((1,), ("data",))
    cfg, opt, st = _rnn_tiny(compress=True)
    step = jax.jit(make_rnn_train_step(cfg, opt, mesh=mesh,
                                       compress_grads=True))
    with use_mesh(mesh):
        st2, m = step(st, _rnn_batch(0, cfg.vocab))
    assert np.isfinite(float(m["loss"]))
    assert sum(float(jnp.sum(jnp.abs(a)))
               for a in jax.tree.leaves(st2.residual)) > 0
    changed = any(not np.array_equal(np.asarray(a), np.asarray(b))
                  for a, b in zip(jax.tree.leaves(st.bn_state),
                                  jax.tree.leaves(st2.bn_state)))
    assert changed
