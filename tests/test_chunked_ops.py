"""Chunked recurrences vs naive step-by-step oracles (the TPU block
decompositions must be exact reformulations, not approximations)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, not error, when absent
from hypothesis import given, settings, strategies as st

from repro.models import mamba2 as M
from repro.models import rwkv6 as R
from repro.models.layers import attention, _sdpa

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")


# --- SSD (mamba2) ------------------------------------------------------------

def _ssd_naive(x, dt, A, Bm, Cm):
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    h = jnp.zeros((Bsz, H, N, P))
    ys = []
    for t in range(S):
        y, h = M.ssd_step(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], h)
        ys.append(y)
    return jnp.stack(ys, axis=1), h


@given(st.integers(0, 10_000), st.sampled_from([4, 8, 16]),
       st.sampled_from([8, 12, 16]))
def test_ssd_chunked_equals_naive(seed, chunk, S):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 4)
    Bsz, H, P, N = 2, 3, 4, 5
    x = jax.random.normal(ks[0], (Bsz, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bsz, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (Bsz, S, N)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(k, 9), (Bsz, S, N)) * 0.5
    y_naive, h_naive = _ssd_naive(x, dt, A, Bm, Cm)
    y_chunk, h_chunk = M.ssd_chunked(x, dt, A, Bm, Cm, chunk)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h_naive),
                               rtol=1e-4, atol=1e-4)


def test_ssd_chunked_with_initial_state_and_padding():
    """Non-multiple seq length + nonzero h0 (prefill-then-decode contract)."""
    k = jax.random.PRNGKey(1)
    ks = jax.random.split(k, 5)
    Bsz, S, H, P, N = 1, 11, 2, 4, 3
    x = jax.random.normal(ks[0], (Bsz, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bsz, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (Bsz, S, N)) * 0.5
    Cm = jax.random.normal(ks[4], (Bsz, S, N)) * 0.5
    h0 = jax.random.normal(jax.random.fold_in(k, 7), (Bsz, H, N, P)) * 0.3

    y_full, h_full = M.ssd_chunked(x, dt, A, Bm, Cm, chunk=4, h0=h0)
    # naive from the same h0
    h = h0
    ys = []
    for t in range(S):
        y, h = M.ssd_step(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], h)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(jnp.stack(ys, 1)),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(h), rtol=1e-4,
                               atol=1e-4)


# --- WKV6 (rwkv) -------------------------------------------------------------

def _wkv_naive(r, k, v, logw, u, S0):
    Bsz, T, H, N = r.shape
    S = S0
    ys = []
    for t in range(T):
        y, S = R.wkv6_step(r[:, t], k[:, t], v[:, t], logw[:, t], u, S)
        ys.append(y)
    return jnp.stack(ys, axis=1), S


@given(st.integers(0, 10_000), st.sampled_from([4, 8]), st.sampled_from([8, 13]))
def test_wkv6_chunked_equals_naive(seed, chunk, T):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    Bsz, H, N = 2, 2, 4
    r = jax.random.normal(ks[0], (Bsz, T, H, N)) * 0.5
    k = jax.random.normal(ks[1], (Bsz, T, H, N)) * 0.5
    v = jax.random.normal(ks[2], (Bsz, T, H, N)) * 0.5
    logw = -jnp.exp(jax.random.normal(ks[3], (Bsz, T, H, N)) * 0.3 - 1.0)
    u = jax.random.normal(ks[4], (H, N)) * 0.3
    S0 = jax.random.normal(jax.random.fold_in(key, 5), (Bsz, H, N, N)) * 0.2

    y_c, S_c = R.wkv6_chunked(r, k, v, logw, u, chunk, S0)
    y_n, S_n = _wkv_naive(r, k, v, logw, u, S0)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_n), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(S_c), np.asarray(S_n), rtol=1e-4,
                               atol=1e-4)


# --- attention ---------------------------------------------------------------

def _mha_ref(q, k, v, causal, window):
    """Dense reference with repeated KV (the layout the GQA einsum replaces)."""
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    k = jnp.repeat(k, Hq // Hkv, axis=2)
    v = jnp.repeat(v, Hq // Hkv, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    Skv = k.shape[1]
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= kp > qp - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (6, 1)])
@pytest.mark.parametrize("window", [0, 5])
def test_gqa_attention_vs_repeat_reference(hq, hkv, window):
    key = jax.random.PRNGKey(0)
    B, S, hd = 2, 12, 8
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, S, hq, hd))
    k = jax.random.normal(jax.random.fold_in(key, 2), (B, S, hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 3), (B, S, hkv, hd))
    out = attention(q, k, v, causal=True, window=window, chunk=1024)
    expect = _mha_ref(q, k, v, True, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


def test_chunked_attention_equals_unchunked():
    key = jax.random.PRNGKey(7)
    B, S, H, hd = 1, 32, 2, 4
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 3), (B, S, H, hd))
    full = attention(q, k, v, causal=True, chunk=1024)
    chunked = attention(q, k, v, causal=True, chunk=8)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


def test_sliding_window_kv_slice_path():
    """The windowed KV-slicing fast path == plain masked computation."""
    key = jax.random.PRNGKey(8)
    B, S, H, hd, win = 1, 64, 1, 4, 8
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(key, 3), (B, S, H, hd))
    sliced = attention(q, k, v, causal=True, window=win, chunk=16)  # slices KV
    ref = _mha_ref(q, k, v, True, win)
    np.testing.assert_allclose(np.asarray(sliced), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
