"""qwen3-1.7b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-1.7B]."""
from repro.configs.base import ModelConfig
from repro.core.quantize import QuantSpec


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b",
        family="dense",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv=8,
        head_dim=128,
        d_ff=6144,
        vocab=151936,
        qk_norm=True,
        rope_theta=1000000.0,
        block_pattern=("full",),
        tie_embeddings=True,
        quant=QuantSpec(mode="ternary", norm="channel"),
    )
