"""zamba2-1.2b [hybrid] — 38 Mamba2 layers + a SHARED full-attention block
applied every 6 layers (weight reuse is the Zamba2 signature)
[arXiv:2411.15242].  SSM state 64, headdim 64 -> 64 SSD heads."""
from repro.configs.base import ModelConfig
from repro.core.quantize import QuantSpec


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv=32,
        head_dim=64,
        d_ff=8192,
        vocab=32000,
        ssm_state=64,
        ssm_expand=2,
        ssm_headdim=64,
        attn_every=6,
        ssm_chunk=64,
        sub_quadratic=True,
        quant=QuantSpec(mode="ternary", norm="channel"),
    )
