"""whisper-base [audio] — enc-dec backbone; the conv/mel frontend is a STUB
(input_specs supplies precomputed (B, S, 512) frame embeddings)
[arXiv:2212.04356].  seq_len shapes refer to encoder frames; decoder length
is min(448, max(64, S//8)) per DESIGN.md."""
from repro.configs.base import ModelConfig
from repro.core.quantize import QuantSpec


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base",
        family="audio",
        n_layers=6,          # decoder
        n_enc_layers=6,
        d_model=512,
        n_heads=8,
        n_kv=8,
        head_dim=64,
        d_ff=2048,
        vocab=51865,
        mlp="gelu",
        block_pattern=("selfcross",),
        max_target_len=448,
        quant=QuantSpec(mode="ternary", norm="channel"),
    )
