"""rwkv6-7b [ssm] — Finch, attention-free, data-dependent decay
[arXiv:2404.05892].  head size 64 -> 64 heads at d_model 4096."""
from repro.configs.base import ModelConfig
from repro.core.quantize import QuantSpec


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b",
        family="ssm",
        n_layers=32,
        d_model=4096,
        n_heads=64,
        n_kv=64,
        head_dim=64,
        d_ff=14336,
        vocab=65536,
        block_pattern=("rwkv",),
        ssm_chunk=64,
        sub_quadratic=True,
        quant=QuantSpec(mode="ternary", norm="channel"),
    )
