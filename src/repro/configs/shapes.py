"""The assigned input-shape set and per-(arch x shape) input specs.

`input_specs(cfg, shape_name)` returns ShapeDtypeStruct stand-ins for every
input of the step being lowered — weak-type-correct, shardable, no device
allocation — plus which step function the cell lowers ('train' | 'prefill' |
'decode').
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(is_applicable, reason-if-not).  long_500k needs sub-quadratic
    attention; pure full-attention archs skip it (DESIGN.md §5)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch — long_500k needs sub-quadratic attention"
    return True, ""


def whisper_dec_len(S: int) -> int:
    return min(448, max(64, S // 8))


def token_batch(cfg: ModelConfig, B: int, S: int) -> dict:
    """Train-step inputs as ShapeDtypeStructs."""
    i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
    bf = lambda *s: jax.ShapeDtypeStruct(s, jnp.dtype(cfg.dtype))
    if cfg.family == "audio":
        d = whisper_dec_len(S)
        return {"tokens": i32(B, d), "targets": i32(B, d),
                "enc_frames": bf(B, S, cfg.d_model)}
    batch = {"tokens": i32(B, S), "targets": i32(B, S)}
    if cfg.family == "vlm":
        batch["img"] = bf(B, cfg.n_img_tokens, cfg.d_model)
    return batch


def prefill_inputs(cfg: ModelConfig, B: int, S: int) -> dict:
    i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
    bf = lambda *s: jax.ShapeDtypeStruct(s, jnp.dtype(cfg.dtype))
    if cfg.family == "audio":
        return {"tokens": i32(B, whisper_dec_len(S)),
                "enc_frames": bf(B, S, cfg.d_model)}
    out = {"tokens": i32(B, S)}
    if cfg.family == "vlm":
        out["img"] = bf(B, cfg.n_img_tokens, cfg.d_model)
    return out


def decode_inputs(cfg: ModelConfig, B: int) -> dict:
    return {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32)}


def decode_context(cfg: ModelConfig, S: int) -> tuple[int, int]:
    """(self-attn context, cross source length) for a decode cell at context S."""
    if cfg.family == "audio":
        return whisper_dec_len(S), S  # decoder ctx, encoder frames in cross-KV
    src = cfg.n_img_tokens if cfg.family == "vlm" else 0
    return S, src
