"""The paper's own experimental model configurations (Tables 1-6).

These are `RNNConfig`s for core/bnlstm.py, named after the paper's tasks.
Sizes follow Appendix C exactly; the benchmark harness trains reduced-scale
versions of the same configs (CPU container) and reports both the exact
analytic memory sizes of the full configs and the measured quality of the
reduced runs.
"""
from __future__ import annotations

import dataclasses

from repro.core.bnlstm import RNNConfig
from repro.core.quantize import QuantSpec


def _rnn(vocab, hidden, layers=1, cell="lstm", mode="ternary") -> RNNConfig:
    return RNNConfig(vocab=vocab, d_hidden=hidden, n_layers=layers, cell=cell,
                     quant=QuantSpec(mode=mode, norm="batch"))


# --- character-level LM (Table 1, 2, 6) ------------------------------------
# PTB: 1000 units, vocab ~50 chars; War&Peace / Linux Kernel: 512 units.
def char_ptb(cell="lstm", mode="ternary") -> RNNConfig:
    return _rnn(50, 1000, cell=cell, mode=mode)


def char_war_peace(cell="lstm", mode="ternary") -> RNNConfig:
    return _rnn(87, 512, cell=cell, mode=mode)


def char_linux(cell="lstm", mode="ternary") -> RNNConfig:
    return _rnn(101, 512, cell=cell, mode=mode)


def char_text8(mode="ternary") -> RNNConfig:
    return _rnn(27, 2000, mode=mode)


# --- word-level LM (Table 3) ------------------------------------------------
def word_ptb_small(mode="ternary") -> RNNConfig:
    return _rnn(10000, 300, mode=mode)


def word_ptb_medium(mode="ternary") -> RNNConfig:
    return _rnn(10000, 650, mode=mode)


def word_ptb_large(mode="ternary") -> RNNConfig:
    return _rnn(10000, 1500, layers=2, mode=mode)


# --- sequential MNIST (Table 4): 100 units, pixel-by-pixel -------------------
def seq_mnist(mode="ternary") -> RNNConfig:
    # vocab field doubles as input dim for the classification wrapper
    return _rnn(256, 100, mode=mode)


def reduced(cfg: RNNConfig, hidden: int = 64) -> RNNConfig:
    """CPU-scale variant of the same config (same code paths)."""
    return dataclasses.replace(cfg, d_hidden=hidden)
