"""Architecture registry: every assigned arch + the paper's own RNN models."""
from __future__ import annotations

from importlib import import_module

from repro.configs.base import ModelConfig

_ARCH_MODULES = {
    "llama3-8b": "llama3_8b",
    "qwen3-0.6b": "qwen3_0_6b",
    "qwen3-1.7b": "qwen3_1_7b",
    "gemma3-27b": "gemma3_27b",
    "rwkv6-7b": "rwkv6_7b",
    "whisper-base": "whisper_base",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "zamba2-1.2b": "zamba2_1_2b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "mixtral-8x7b": "mixtral_8x7b",
}

ARCH_IDS = tuple(_ARCH_MODULES)

# The paper's own BN-LSTM arch, servable through the unified recurrent
# runtime (serve/recurrent.py).  Kept out of ARCH_IDS on purpose: these are
# RNNConfig, not ModelConfig, and the transformer-pool tests iterate ARCH_IDS.
RNN_ARCH_IDS = ("rnn-paper",)


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    return import_module(f"repro.configs.{_ARCH_MODULES[name]}").config()


def get_rnn_config(name: str):
    """RNNConfig for a paper arch (full scale; `rnn_paper.reduced` shrinks)."""
    if name not in RNN_ARCH_IDS:
        raise KeyError(f"unknown RNN arch {name!r}; known: {RNN_ARCH_IDS}")
    from repro.configs import rnn_paper
    return rnn_paper.char_ptb()
