"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention on every
layer (ring KV makes it long_500k-eligible) [arXiv:2401.04088]."""
from repro.configs.base import ModelConfig
from repro.core.quantize import QuantSpec


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv=8,
        head_dim=128,
        d_ff=14336,
        vocab=32000,
        rope_theta=1000000.0,
        block_pattern=("full",),
        n_experts=8,
        topk=2,
        window=4096,
        swa_all=True,
        sub_quadratic=True,
        quant=QuantSpec(mode="ternary", norm="channel"),
    )
