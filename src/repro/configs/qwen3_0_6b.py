"""qwen3-0.6b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-0.6B]."""
from repro.configs.base import ModelConfig
from repro.core.quantize import QuantSpec


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b",
        family="dense",
        n_layers=28,
        d_model=1024,
        n_heads=16,
        n_kv=8,
        head_dim=128,
        d_ff=3072,
        vocab=151936,
        qk_norm=True,
        rope_theta=1000000.0,
        block_pattern=("full",),
        tie_embeddings=True,
        quant=QuantSpec(mode="ternary", norm="channel"),
    )
