"""qwen3-moe-30b-a3b [moe] — 128 experts, top-8, d_ff=768 per expert
[hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import ModelConfig
from repro.core.quantize import QuantSpec


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv=4,
        head_dim=128,
        d_ff=768,
        vocab=151936,
        qk_norm=True,
        rope_theta=1000000.0,
        block_pattern=("full",),
        n_experts=128,
        topk=8,
        quant=QuantSpec(mode="ternary", norm="channel"),
    )
