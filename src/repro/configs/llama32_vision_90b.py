"""llama-3.2-vision-90b [vlm] — cross-attn image layers every 5th block;
vision tower is a STUB (input_specs supplies (B, 1600, 8192) patch embeddings)
[hf:meta-llama/Llama-3.2-90B-Vision]."""
from repro.configs.base import ModelConfig
from repro.core.quantize import QuantSpec


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b",
        family="vlm",
        n_layers=100,
        d_model=8192,
        n_heads=64,
        n_kv=8,
        head_dim=128,
        d_ff=28672,
        vocab=128256,
        rope_theta=500000.0,
        block_pattern=("self", "self", "self", "self", "cross"),
        n_img_tokens=1600,
        quant=QuantSpec(mode="ternary", norm="channel"),
    )
