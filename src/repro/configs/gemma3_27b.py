"""gemma3-27b [dense] — 5:1 local:global attention, 1024-token window, 256k
vocab [hf:google/gemma-3-27b-pt].  Local ring-KV makes it long_500k-eligible
(the ~10 global layers hold the full context, head/length-sharded)."""
from repro.configs.base import ModelConfig
from repro.core.quantize import QuantSpec


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b",
        family="dense",
        n_layers=62,
        d_model=5376,
        n_heads=32,
        n_kv=16,
        head_dim=128,
        d_ff=21504,
        vocab=262144,
        qk_norm=True,
        rope_theta=1000000.0,
        block_pattern=("local", "local", "local", "local", "local", "global"),
        window=1024,
        sub_quadratic=True,
        quant=QuantSpec(mode="ternary", norm="channel"),
    )
