"""Unified model configuration for every assigned architecture.

One frozen dataclass covers the whole pool; family-specific fields are ignored
by families that don't use them.  `reduced()` produces the small smoke-test
variant of the same family (same code paths, tiny dims).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.quantize import QuantSpec


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 500000.0
    causal: bool = True
    mlp: str = "swiglu"  # swiglu | gelu
    attn_softcap: float = 0.0
    tie_embeddings: bool = False

    # heterogeneous layer stacks: repeating pattern of layer kinds
    # kinds: full | local | global | self | cross | mamba | rwkv
    block_pattern: Tuple[str, ...] = ("full",)
    window: int = 0  # sliding window for 'local' kind / swa_all
    swa_all: bool = False  # mixtral: SWA on every layer

    # moe
    n_experts: int = 0
    topk: int = 0
    capacity_factor: float = 1.25

    # ssm / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    attn_every: int = 0  # zamba2: shared attn block period

    # enc-dec (whisper): n_layers = decoder depth
    n_enc_layers: int = 0
    max_target_len: int = 448

    # vlm
    n_img_tokens: int = 0

    # quantization (the paper's technique)
    quant: QuantSpec = QuantSpec(mode="none")

    # numerics / structure
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"  # full | dots (save matmul outputs: ~8ND->6ND
    #                             train flops for more checkpoint memory)
    attn_chunk: int = 1024
    ssm_chunk: int = 64
    vocab_pad_to: int = 128
    # replace the over-repeats lax.scan with a python loop.  Used by the
    # dry-run's scan-correction compiles (XLA cost_analysis counts a loop
    # body once, not x trip-count) and available for small-depth runs.
    unroll: bool = False
    # PaLM-style parallel attention+MLP residual: one shared pre-norm, the
    # two row-parallel outputs sum BEFORE the TP all-reduce, halving the
    # per-layer activation collectives (beyond-paper §Perf variant; changes
    # the model function, so it is a training-time architecture choice).
    parallel_block: bool = False

    # capability flags
    sub_quadratic: bool = False  # eligible for long_500k

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return (self.vocab + p - 1) // p * p

    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def with_quant(self, spec: QuantSpec) -> "ModelConfig":
        return dataclasses.replace(self, quant=spec)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        pat = self.block_pattern
        n_layers = max(len(pat), 2) if self.family != "hybrid" else max(self.attn_every + 1, 4)
        return dataclasses.replace(
            self,
            n_layers=n_layers,
            d_model=64,
            n_heads=4,
            n_kv=min(self.n_kv, 2) if self.n_kv < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab=256,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            topk=min(self.topk, 2) if self.topk else 0,
            window=min(self.window, 16) if self.window else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=16,
            n_enc_layers=min(self.n_enc_layers, 2),
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            n_img_tokens=min(self.n_img_tokens, 16) if self.n_img_tokens else 0,
            attn_chunk=32,
            ssm_chunk=8,
            dtype="float32",
        )


# `head_dim` note: configs specify d_model and n_heads; where the public model
# card gives an explicit head_dim != d_model/n_heads (gemma3, qwen3) we set it.
