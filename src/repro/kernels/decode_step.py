"""Fused Pallas decode-step kernel for the BN-LSTM / BN-GRU serving path.

One recurrent serving step against a *packed* recurrent weight is, unfused,
~6 separate jitted ops: packed GEMV, alpha scale, BN affine, bias add, gate
split, nonlinearities + cell update.  At decode the GEMV is (1..B, H) — pure
memory traffic — so every extra launch round-trips the tiny activations
through HBM.  This kernel does the whole step in ONE launch (DESIGN.md §6):

  * the h-side GEMV against gate-aligned packed codes (2-bit ternary / 1-bit
    binary, decoded to ±1/0 on the VPU exactly like kernels/packed_matmul.py),
  * the per-column frozen-BN affine (scale folds the QTensor alpha),
  * the input-side pre-activation + bias add (`ax`, computed by the caller —
    for layer 0 it is a single gather of the BN-folded row table),
  * the gate nonlinearities and hidden/cell update (LSTM or GRU).

Tiling: grid over 128-wide tiles of the gate width H; every gate's code
block for a tile arrives stacked along a leading gate axis, so the cell
update has f/i/o/g (or r/z/g) together without cross-tile traffic.  The
previous hidden vector (the GEMV operand) rides along whole — it is (B, Hp)
and tiny.  All operands arrive padded from `ops.fused_rnn_decode_step`:
B to a sublane multiple, H to the 128-lane tile (per gate, so gate
boundaries stay tile-aligned; pad K lanes multiply zero-padded activations
and contribute nothing).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quantize import BINARY_GROUP, TERNARY_GROUP
from repro.kernels.packed_matmul import (_unpack_binary_tile,
                                         _unpack_ternary_tile)

Array = jax.Array

BN_TILE = 128  # lane tile over the gate width


def _gates(x, codes_ref, ax_ref, scale_ref, shift_ref, hp: int, mode: str,
           n_gates: int):
    """Per-gate pre-activations a_i = (x @ W_i) * scale_i + shift_i + ax_i."""
    unpack = _unpack_ternary_tile if mode == "ternary" else _unpack_binary_tile
    out = []
    for i in range(n_gates):
        w = unpack(codes_ref[i], hp).astype(x.dtype)
        a = jnp.dot(x, w, preferred_element_type=jnp.float32)
        out.append(a * scale_ref[i:i + 1, :] + shift_ref[i:i + 1, :]
                   + ax_ref[:, i, :])
    return out


def _lstm_kernel(x_ref, c_ref, hprev_ref, live_ref, codes_ref, ax_ref,
                 scale_ref, shift_ref, cs_ref, ct_ref, h_out, c_out,
                 *, hp: int, mode: str):
    f, i, o, g = _gates(x_ref[...], codes_ref, ax_ref, scale_ref, shift_ref,
                        hp, mode, 4)
    c_new = jax.nn.sigmoid(f) * c_ref[...] + jax.nn.sigmoid(i) * jnp.tanh(g)
    cn = c_new * cs_ref[...] + ct_ref[...]  # cell-norm affine (1s/0s when off)
    # continuous batching: dead slots (live == 0) keep h/c bit-for-bit; a
    # select, not a lerp — dead-row garbage may be non-finite and 0*inf=NaN.
    # hprev is the same array as x with a TILE spec, so the select needs no
    # cross-tile reads and the launch shape is occupancy-independent.
    m = live_ref[...] > 0
    h_out[...] = jnp.where(m, jax.nn.sigmoid(o) * jnp.tanh(cn), hprev_ref[...])
    c_out[...] = jnp.where(m, c_new, c_ref[...])


def _gru_kernel(x_ref, h_ref, live_ref, codes_ref, ax_ref, scale_ref,
                shift_ref, h_out, *, hp: int, mode: str):
    # ax already includes the bias; the h-side BN shift is NOT folded into ax
    # because r gates the whole normalized ah_g term (core/bnlstm._gru_step).
    unpack = _unpack_ternary_tile if mode == "ternary" else _unpack_binary_tile
    x = x_ref[...]
    ah = []
    for i in range(3):
        w = unpack(codes_ref[i], hp).astype(x.dtype)
        a = jnp.dot(x, w, preferred_element_type=jnp.float32)
        ah.append(a * scale_ref[i:i + 1, :] + shift_ref[i:i + 1, :])
    r = jax.nn.sigmoid(ax_ref[:, 0, :] + ah[0])
    z = jax.nn.sigmoid(ax_ref[:, 1, :] + ah[1])
    g = jnp.tanh(ax_ref[:, 2, :] + r * ah[2])
    h_new = (1.0 - z) * h_ref[...] + z * g
    h_out[...] = jnp.where(live_ref[...] > 0, h_new, h_ref[...])


def fused_decode_step(x: Array, carry: Array, codes: Array, ax: Array,
                      scale: Array, shift: Array, cscale: Array, cshift: Array,
                      live: Array, *, cell: str, mode: str,
                      interpret: bool | None = None):
    """Padded-operand entry (see ops.fused_rnn_decode_step for the public API).

    x, carry: (Bp, Hp) fp32; codes: (g, Hp/G, Hp) uint32 gate-aligned;
    ax: (Bp, g, Hp); scale/shift: (g, Hp); cscale/cshift: (1, Hp);
    live: (Bp, Hp) fp32 0/1 row mask (all-ones when every slot is live —
    the mask is ALWAYS an operand, so masked and unmasked ticks share one
    launch signature and occupancy changes never relaunch a new shape).
    Returns (h', c') fp32 (Bp, Hp) for LSTM, h' alone for GRU.
    """
    group = TERNARY_GROUP if mode == "ternary" else BINARY_GROUP
    g, kg, hp = codes.shape
    bp = x.shape[0]
    if hp % BN_TILE or kg * group != hp:
        raise ValueError(f"codes {codes.shape} must be Hp/{group} x Hp with "
                         f"Hp % {BN_TILE} == 0")
    if live.shape != (bp, hp):
        raise ValueError(f"live mask {live.shape} must match padded ({bp}, {hp})")
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bn = BN_TILE
    grid = (hp // bn,)

    full = pl.BlockSpec((bp, hp), lambda j: (0, 0))
    tile = pl.BlockSpec((bp, bn), lambda j: (0, j))
    cspec = pl.BlockSpec((g, kg, bn), lambda j: (0, 0, j))
    axspec = pl.BlockSpec((bp, g, bn), lambda j: (0, 0, j))
    vspec = pl.BlockSpec((g, bn), lambda j: (0, j))
    rowspec = pl.BlockSpec((1, bn), lambda j: (0, j))
    oshape = jax.ShapeDtypeStruct((bp, hp), jnp.float32)

    if cell == "lstm":
        kernel = functools.partial(_lstm_kernel, hp=hp, mode=mode)
        return pl.pallas_call(
            kernel,
            grid=grid,
            # x rides along twice: once whole (the GEMV operand) and once
            # tiled (hprev for the dead-slot select)
            in_specs=[full, tile, tile, tile, cspec, axspec, vspec, vspec,
                      rowspec, rowspec],
            out_specs=(tile, tile),
            out_shape=(oshape, oshape),
            interpret=interpret,
            name=f"{mode}_lstm_decode_step",
        )(x, carry, x, live, codes, ax, scale, shift, cscale, cshift)
    kernel = functools.partial(_gru_kernel, hp=hp, mode=mode)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[full, tile, tile, cspec, axspec, vspec, vspec],
        out_specs=tile,
        out_shape=oshape,
        interpret=interpret,
        name=f"{mode}_gru_decode_step",
    )(x, carry, live, codes, ax, scale, shift)
