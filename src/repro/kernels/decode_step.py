"""Whole-tick fused Pallas kernel for BN-LSTM / BN-GRU serving (DESIGN.md
§11).

One batched decode tick — ALL layers, the logits head, and greedy argmax —
is ONE Pallas launch.  Unfused, a tick is ~6 ops per layer plus the head:
every one round-trips the tiny (B, H) activations through HBM, and at
decode the GEMVs are pure memory traffic, so launch overhead and HBM hops
dominate.  This kernel keeps h and c for every layer in VMEM across the
whole tick:

  * the h-side GEMV per layer runs ACCUMULATION-ONLY against gate-aligned
    packed codes (`packed_matmul.accumulate_gemv`: codes decode to boolean
    plus/minus masks, activations are selected and summed — zero multiplies
    on the weight path, asserted statically in tier-1),
  * the per-column frozen-BN affine (scale folds the QTensor alpha) and the
    gate nonlinearities + cell update (LSTM or GRU) follow in-register,
  * layers >= 1 compute their input-side pre-activation in-kernel, the same
    accumulation-only GEMV against the stacked x-side codes (scale folds
    alpha, shift folds the BN shift AND the bias); layer 0's token gather
    happens outside (it is an XLA gather, not a launch),
  * the `live` mask freezes dead continuous-batching rows in-kernel — a
    select, not a lerp, so dead-row garbage (possibly non-finite) never
    propagates,
  * optionally (static `with_head`, on when the padded head fits VMEM) the
    fp logits head and a greedy argmax run in the same launch.

Everything arrives padded from `ops.fused_decode_tick`: B to a sublane
multiple, H per gate to the 128-lane tile, codes' K rows to Hp/GROUP.  Pad
lanes carry zero activations, zero affine scale/shift and zero bias, so
pad h/c stay exactly 0.0 across layers (binary's pad-code-decodes-to-−1
quirk contributes select(minus, 0, 0) = 0) and pad logits columns sit at
finfo.min via the padded bias, below any real logit the argmax could pick.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quantize import BINARY_GROUP, TERNARY_GROUP
from repro.kernels import dispatch
from repro.kernels.packed_matmul import accumulate_gemv

Array = jax.Array

BN_TILE = 128  # lane tile the gate width is padded to


def _tick_kernel(ax0_ref, h_ref, c_ref, live_ref, ch_ref, cx_ref, sh_ref,
                 th_ref, sx_ref, tx_ref, sc_ref, tc_ref, *refs,
                 cell: str, mode: str, n_layers: int, n_gates: int,
                 with_head: bool):
    if with_head:
        ws_ref, bs_ref, h_out, c_out, lg_out, tok_out = refs
    else:
        h_out, c_out = refs

    ax = ax0_ref[...]            # (Bp, g, Hp) — layer 0, gathered outside
    live = live_ref[...] > 0     # (Bp, Hp)
    h_new = None
    for l in range(n_layers):
        h_prev = h_ref[l]
        c_prev = c_ref[l]
        # accumulation-only h-side GEMV per gate; the BN scale below folds
        # the QTensor alpha, so the codes stay raw ±1/0 masks
        ah = [accumulate_gemv(h_prev, ch_ref[l, i], mode=mode)
              for i in range(n_gates)]
        if cell == "lstm":
            f, i_, o, g = [ah[i] * sh_ref[l, i:i + 1, :]
                           + th_ref[l, i:i + 1, :] + ax[:, i]
                           for i in range(4)]
            c_new = jax.nn.sigmoid(f) * c_prev + jax.nn.sigmoid(i_) * jnp.tanh(g)
            cn = c_new * sc_ref[l] + tc_ref[l]  # cell norm (1s/0s when off)
            # dead slots keep h/c bit-for-bit: a select, not a lerp —
            # dead-row garbage may be non-finite and 0*inf=NaN
            h_new = jnp.where(live, jax.nn.sigmoid(o) * jnp.tanh(cn), h_prev)
            c_sel = jnp.where(live, c_new, c_prev)
        else:
            # the h-side BN shift is NOT folded into ax: r gates the whole
            # normalized ah_g term (core/bnlstm._gru_step)
            ahn = [ah[i] * sh_ref[l, i:i + 1, :] + th_ref[l, i:i + 1, :]
                   for i in range(3)]
            r = jax.nn.sigmoid(ax[:, 0] + ahn[0])
            z = jax.nn.sigmoid(ax[:, 1] + ahn[1])
            g = jnp.tanh(ax[:, 2] + r * ahn[2])
            h_new = jnp.where(live, (1.0 - z) * h_prev + z * g, h_prev)
            c_sel = c_prev  # GRU carries no cell
        h_out[l] = h_new
        c_out[l] = c_sel
        if l + 1 < n_layers:
            # next layer's input-side preact, in-kernel: scale folds the
            # x-side alpha, shift folds BN shift + bias
            ax = jnp.stack(
                [accumulate_gemv(h_new, cx_ref[l, i], mode=mode)
                 * sx_ref[l, i:i + 1, :] + tx_ref[l, i:i + 1, :]
                 for i in range(n_gates)], axis=1)

    if with_head:
        # fp head: multiplies here consume the fused tick's OUTPUT
        # activations against the fp head weight — the mul-free claim is
        # about the packed weight path, which ended at h_new
        lg = jnp.dot(h_new, ws_ref[...], preferred_element_type=jnp.float32) \
            + bs_ref[...]
        lg_out[...] = lg
        vp = lg.shape[-1]
        mx = jnp.max(lg, axis=-1, keepdims=True)
        col = jax.lax.broadcasted_iota(jnp.int32, lg.shape, 1)
        idx = jnp.min(jnp.where(lg == mx, col, vp), axis=-1, keepdims=True)
        tok_out[...] = jnp.broadcast_to(idx, tok_out.shape)


def fused_tick(ax0: Array, h: Array, c: Array, live: Array, codes_h: Array,
               codes_x: Array, scale_h: Array, shift_h: Array,
               scale_x: Array, shift_x: Array, scale_c: Array,
               shift_c: Array, ws, bs, *, cell: str, mode: str,
               interpret: bool | None = None):
    """Padded-operand entry (see ops.fused_decode_tick for the public API).

    ax0: (Bp, g, Hp) layer-0 input preact (bias folded); h/c: (L, Bp, Hp);
    live: (Bp, Hp) fp32 0/1 row mask (all-ones when every slot is live — the
    mask is ALWAYS an operand, so masked and unmasked ticks share one launch
    signature and occupancy changes never relaunch a new shape);
    codes_h: (L, g, Hp/G, Hp) uint32; codes_x: (max(L-1,1), g, Hp/G, Hp);
    scale_h/shift_h: (L, g, Hp); scale_x/shift_x like codes_x's leading dim;
    scale_c/shift_c: (L, 1, Hp); ws: (Hp, Vp) fp32 + bs: (1, Vp) enable the
    in-kernel head (pass None to skip it — wrapper applies the head outside
    when it would not fit VMEM).

    Returns (h', c') or (h', c', logits (Bp, Vp), greedy (Bp, TILE) int32).
    """
    group = TERNARY_GROUP if mode == "ternary" else BINARY_GROUP
    L, g, kg, hp = codes_h.shape
    bp = ax0.shape[0]
    if hp % BN_TILE or kg * group != hp:
        raise ValueError(f"codes {codes_h.shape} must be Hp/{group} x Hp "
                         f"with Hp % {BN_TILE} == 0")
    if h.shape != (L, bp, hp) or live.shape != (bp, hp):
        raise ValueError(f"state {h.shape} / live {live.shape} must match "
                         f"padded ({L}, {bp}, {hp})")
    with_head = ws is not None
    interpret = dispatch.resolve_interpret(interpret)

    kernel = functools.partial(_tick_kernel, cell=cell, mode=mode,
                               n_layers=L, n_gates=g, with_head=with_head)
    state_shape = jax.ShapeDtypeStruct((L, bp, hp), jnp.float32)
    out_shape = [state_shape, state_shape]
    args = [ax0, h, c, live, codes_h, codes_x, scale_h, shift_h, scale_x,
            shift_x, scale_c, shift_c]
    if with_head:
        vp = ws.shape[1]
        args += [ws, bs]
        out_shape += [jax.ShapeDtypeStruct((bp, vp), jnp.float32),
                      jax.ShapeDtypeStruct((bp, BN_TILE), jnp.int32)]
    dispatch.count_launch(f"{mode}_{cell}_decode_tick")
    return pl.pallas_call(
        kernel,
        out_shape=tuple(out_shape),
        interpret=interpret,
        name=f"{mode}_{cell}_decode_tick",
    )(*args)
