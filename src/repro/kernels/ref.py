"""Pure-jnp oracles for every Pallas kernel in this package.

These define the semantics; the kernels must match them (tests sweep shapes
and dtypes and assert allclose in interpret mode).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantize import (pack_binary, pack_ternary, unpack_binary,
                                 unpack_ternary)

Array = jax.Array


def ternary_matmul_ref(x: Array, wp: Array, k: int, alpha: float = 1.0) -> Array:
    """x: (M, K) @ alpha * unpack(wp (K//16, N)) -> (M, N) fp32."""
    w = unpack_ternary(wp, k, dtype=x.dtype)
    return alpha * jnp.dot(x, w, preferred_element_type=jnp.float32)


def binary_matmul_ref(x: Array, wp: Array, k: int, alpha: float = 1.0) -> Array:
    w = unpack_binary(wp, k, dtype=x.dtype)
    return alpha * jnp.dot(x, w, preferred_element_type=jnp.float32)


def quantize_pack_ternary_ref(w: Array, u: Array, alpha: float) -> Array:
    """Stochastic ternarize (paper Eq. 5/6) then 2-bit pack."""
    wn = jnp.clip(w / alpha, -1.0, 1.0)
    nz = (u < jnp.abs(wn)).astype(w.dtype)
    t = nz * jnp.sign(wn)
    return pack_ternary(t)


def quantize_pack_binary_ref(w: Array, u: Array, alpha: float) -> Array:
    """Stochastic binarize (paper Eq. 4/6) then 1-bit pack."""
    wn = jnp.clip(w / alpha, -1.0, 1.0)
    p_one = (wn + 1.0) * 0.5
    b = jnp.where(u < p_one, 1.0, -1.0).astype(w.dtype)
    return pack_binary(b)
