"""jit'd public wrappers around the Pallas kernels: padding to block
multiples, alpha scaling, dtype handling, and a serving-oriented
`PackedLinear` that stores weights packed in HBM."""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantize import (BINARY_GROUP, TERNARY_GROUP, pack_binary,
                                 pack_ternary)
from repro.kernels import packed_matmul as PK

Array = jax.Array


def _pad_to(x: Array, m: int, axis: int) -> Array:
    r = x.shape[axis] % m
    if r == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, m - r)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("k", "mode", "interpret"))
def packed_matmul(x: Array, wp: Array, k: int, alpha=1.0, *, mode: str = "ternary",
                  interpret: Optional[bool] = None) -> Array:
    """y = alpha * (x @ unpack(wp)).  x: (..., K); wp: (K/G, N) uint32.

    Leading batch dims are flattened into M; M/N/K padded to block multiples.
    """
    group = TERNARY_GROUP if mode == "ternary" else BINARY_GROUP
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = wp.shape[1]
    xm = x.reshape(-1, K)
    M = xm.shape[0]

    bm = 128 if M >= 128 else 8
    bn = 128
    bk = 256 if K % 256 == 0 else group * 8
    xm = _pad_to(_pad_to(xm, bm, 0), bk, 1)
    wpp = _pad_to(_pad_to(wp, bk // group, 0), bn, 1)
    y = PK.packed_matmul(xm, wpp, xm.shape[1], mode=mode,
                         block=(bm, bn, bk), interpret=interpret)
    y = y[:M, :N] * jnp.asarray(alpha, jnp.float32)
    return y.reshape(*lead, N)


@functools.partial(jax.jit, static_argnames=("mode", "interpret"))
def quantize_pack(w: Array, u: Array, alpha, *, mode: str = "ternary",
                  interpret: Optional[bool] = None) -> Array:
    """Fused stochastic quantize (paper Eq. 4-6) + bit-pack.  w: (K, N) with
    K % GROUP == 0 (weights in this framework are 128-aligned)."""
    group = TERNARY_GROUP if mode == "ternary" else BINARY_GROUP
    K, N = w.shape
    bk = min(256, K) if K % 256 == 0 or K <= 256 else group * 8
    while K % bk:
        bk //= 2
    bk = max(bk, group)
    bn = min(256, N)
    while N % bn:
        bn //= 2
    return PK.quantize_pack(w.astype(jnp.float32), u.astype(jnp.float32),
                            alpha, mode=mode, block=(bk, bn),
                            interpret=interpret)


@dataclasses.dataclass
class PackedLinear:
    """Serving-side layer: weights stored packed (2-bit/1-bit) in HBM.

    Built once from trained master weights (deterministic quantization —
    paper Fig. 1b shows the stochastic/deterministic gap is negligible);
    every apply streams GROUPx fewer weight bytes than fp32.
    """

    wp: Array          # (K/G, N) uint32
    k: int
    alpha: float
    mode: str
    scale: Optional[Array] = None  # channel scale companion (norm='channel')

    @classmethod
    def from_master(cls, w: Array, alpha: float, mode: str,
                    scale: Optional[Array] = None) -> "PackedLinear":
        wn = jnp.clip(w / alpha, -1.0, 1.0)
        if mode == "ternary":
            q = jnp.round(wn)
            wp = pack_ternary(q)
        else:
            q = jnp.where(wn >= 0, 1.0, -1.0)
            wp = pack_binary(q)
        return cls(wp=wp, k=w.shape[0], alpha=float(alpha), mode=mode, scale=scale)

    def __call__(self, x: Array, *, interpret: Optional[bool] = None) -> Array:
        y = packed_matmul(x, self.wp, self.k, self.alpha, mode=self.mode,
                          interpret=interpret)
        if self.scale is not None:
            y = y * self.scale
        return y.astype(x.dtype)

    @property
    def nbytes(self) -> int:
        return self.wp.size * 4
