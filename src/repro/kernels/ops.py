"""jit'd public wrappers around the Pallas kernels: padding to block
multiples, alpha scaling, dtype handling, and `qmatmul` — the single
dispatch entry every matmul call site in the model code goes through.

`qmatmul(x, w)` routes a `QTensor` operand (core/qtensor.py) to the Pallas
packed kernel and an fp operand to `jnp.matmul`, so `rnn_lm_apply`,
`T.prefill` and `T.decode_step` run unmodified against either a training
tree or an exported packed tree."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.qtensor import QTensor
from repro.core.quantize import BINARY_GROUP, TERNARY_GROUP
from repro.kernels import packed_matmul as PK

Array = jax.Array


def _pad_to(x: Array, m: int, axis: int) -> Array:
    r = x.shape[axis] % m
    if r == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, m - r)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("k", "mode", "interpret"))
def packed_matmul(x: Array, wp: Array, k: int, alpha=1.0, *, mode: str = "ternary",
                  interpret: Optional[bool] = None) -> Array:
    """y = alpha * (x @ unpack(wp)).  x: (..., K); wp: (K/G, N) uint32.

    Leading batch dims are flattened into M; M/N/K padded to block multiples.
    """
    group = TERNARY_GROUP if mode == "ternary" else BINARY_GROUP
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = wp.shape[1]
    xm = x.reshape(-1, K)
    M = xm.shape[0]

    bm = 128 if M >= 128 else 8
    bn = 128
    bk = 256 if K % 256 == 0 else group * 8
    xm = _pad_to(_pad_to(xm, bm, 0), bk, 1)
    wpp = _pad_to(_pad_to(wp, bk // group, 0), bn, 1)
    y = PK.packed_matmul(xm, wpp, xm.shape[1], mode=mode,
                         block=(bm, bn, bk), interpret=interpret)
    y = y[:M, :N] * jnp.asarray(alpha, jnp.float32)
    return y.reshape(*lead, N)


@functools.partial(jax.jit, static_argnames=("mode", "interpret"))
def quantize_pack(w: Array, u: Array, alpha, *, mode: str = "ternary",
                  interpret: Optional[bool] = None) -> Array:
    """Fused stochastic quantize (paper Eq. 4-6) + bit-pack.  w: (K, N) with
    K % GROUP == 0 (weights in this framework are 128-aligned)."""
    group = TERNARY_GROUP if mode == "ternary" else BINARY_GROUP
    K, N = w.shape
    bk = min(256, K) if K % 256 == 0 or K <= 256 else group * 8
    while K % bk:
        bk //= 2
    bk = max(bk, group)
    bn = min(256, N)
    while N % bn:
        bn //= 2
    return PK.quantize_pack(w.astype(jnp.float32), u.astype(jnp.float32),
                            alpha, mode=mode, block=(bk, bn),
                            interpret=interpret)


# ---------------------------------------------------------------------------
# qmatmul: the one matmul entry for fp AND packed weights
# ---------------------------------------------------------------------------


def qmatmul(x: Array, w, *, interpret: Optional[bool] = None) -> Array:
    """y = x @ w for fp `w`, or the Pallas packed matmul for `QTensor` w.

    x: (..., K).  A stacked QTensor (codes (L, ..., K/G, N)) is applied
    per-matrix: x's leading axes must start with the same L (expert / layer
    batch), and the L slices run as an unrolled loop (L is small and static —
    experts per layer — and this keeps us off pallas_call batching rules).

    Output dtype follows x (the activation compute dtype); the packed kernel
    accumulates in fp32 either way.
    """
    if not isinstance(w, QTensor):
        return x @ w
    if w.codes.ndim > 2:
        L = w.codes.shape[0]
        if x.shape[0] != L:
            raise ValueError(
                f"stacked QTensor with {L} matrices needs x batched the same "
                f"way, got x {x.shape}")
        sl = lambda i: jax.tree.map(lambda c: c[i], w)
        return jnp.stack([qmatmul(x[i], sl(i), interpret=interpret)
                          for i in range(L)])
    if x.shape[-1] != w.k:
        raise ValueError(f"qmatmul contraction mismatch: x {x.shape} vs "
                         f"QTensor k={w.k}")
    # zero-pad activations to the codes' K coverage: pad lanes multiply
    # zeros, so pack-time pad codes contribute exactly nothing.
    kp = w.codes.shape[-2] * w.group
    if kp != w.k:
        x_in = _pad_to(x.reshape(-1, w.k), w.group, 1).reshape(
            x.shape[:-1] + (kp,))
    else:
        x_in = x
    y = packed_matmul(x_in, w.codes, kp, w.alpha, mode=w.mode,
                      interpret=interpret)
    if w.scale is not None:
        y = y * w.scale
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# fused recurrent decode step (kernels/decode_step.py)
# ---------------------------------------------------------------------------


def prepare_gate_codes(qt: QTensor, n_gates: int) -> Array:
    """Gate-align a packed recurrent weight for the fused decode kernel.

    `qt` packs wh (H, n_gates*H).  Each gate's N columns are sliced out,
    padded to the 128-lane tile Hp (so gate boundaries stay tile-aligned in
    the kernel), the K code rows are padded to Hp/GROUP, and the gates are
    stacked: (n_gates, Hp/G, Hp) uint32.  Pad K codes are harmless — the
    matching activation lanes are zero-padded.  Done ONCE per serving
    session (serve/recurrent.py caches the result in the decode tables)."""
    from repro.kernels.decode_step import BN_TILE

    if qt.scale is not None:
        # the fused kernel folds only alpha * BN-affine into its scale; a
        # per-channel QTensor scale would be silently dropped
        raise ValueError("fused decode does not support channel-scaled "
                         "QTensors (RNN export packs scale-free weights); "
                         "use the unfused path")
    kg, N = qt.codes.shape
    H = N // n_gates
    if H * n_gates != N or qt.k != H:
        raise ValueError(f"expected a square-per-gate (H, {n_gates}*H) "
                         f"recurrent weight, got k={qt.k}, N={N}")
    hp = -(-max(H, 1) // BN_TILE) * BN_TILE
    gates = [jnp.pad(qt.codes[:, i * H:(i + 1) * H],
                     ((0, hp // qt.group - kg), (0, hp - H)))
             for i in range(n_gates)]
    return jnp.stack(gates)


def fused_rnn_decode_step(h: Array, carry: Array, gate_codes: Array,
                          ax: Array, scale: Array, shift: Array,
                          scale_c: Array, shift_c: Array, *, cell: str,
                          mode: str, live: Optional[Array] = None,
                          interpret: Optional[bool] = None):
    """One BN-LSTM/BN-GRU serving step in a single Pallas launch.

    h:     (B, H) previous hidden (the GEMV operand).
    carry: (B, H) previous cell state for LSTM; pass h for GRU.
    gate_codes: (n_gates, Hp/G, Hp) from `prepare_gate_codes`.
    ax:    (B, n_gates*H) input-side BN'd pre-activation INCLUDING the bias.
    scale/shift: (n_gates*H,) frozen h-side BN affine; `scale` must already
           fold the QTensor alpha (the kernel sees raw ±1/0 codes).
    scale_c/shift_c: (H,) cell-norm affine (ones/zeros when cell_norm off).
    live:  optional (B,) bool — continuous-batching occupancy mask; rows
           where live is False return their h/c unchanged (bit-for-bit).
           The kernel ALWAYS receives a mask operand (ones when None), so
           masked and unmasked ticks share one launch signature and
           occupancy changes never change the launch shape.
    Returns (h', c'); c' is the unchanged carry for GRU.
    """
    from repro.kernels import decode_step as DK

    g, kg, hp = gate_codes.shape
    B, H = h.shape
    bp = -(-max(B, 1) // 8) * 8
    f32 = jnp.float32
    pad_m = lambda a: jnp.pad(a.astype(f32),
                              ((0, bp - a.shape[0]), (0, hp - a.shape[1])))
    pad_v = lambda a, r: jnp.pad(a.astype(f32).reshape(r, -1),
                                 ((0, 0), (0, hp - H)))
    ax3 = jnp.pad(ax.astype(f32).reshape(B, g, H),
                  ((0, bp - B), (0, 0), (0, hp - H)))
    if live is None:
        live_m = jnp.ones((bp, hp), f32)
    else:  # pad rows/lanes 0: they select hprev/carry, then get sliced off
        live_m = pad_m(jnp.broadcast_to(live.astype(f32)[:, None], (B, H)))
    args = (pad_m(h), pad_m(carry), gate_codes, ax3,
            pad_v(scale, g), pad_v(shift, g))
    if cell == "lstm":
        hn, cn = DK.fused_decode_step(*args, pad_v(scale_c, 1),
                                      pad_v(shift_c, 1), live_m, cell=cell,
                                      mode=mode, interpret=interpret)
        return hn[:B, :H].astype(h.dtype), cn[:B, :H].astype(h.dtype)
    hn = DK.fused_decode_step(*args, None, None, live_m, cell=cell, mode=mode,
                              interpret=interpret)
    return hn[:B, :H].astype(h.dtype), carry
