"""jit'd public wrappers around the Pallas kernels: padding to block
multiples, alpha scaling, dtype handling, and `qmatmul` — the single
dispatch entry every matmul call site in the model code goes through.

`qmatmul(x, w)` routes a `QTensor` operand (core/qtensor.py) to the Pallas
packed kernel and an fp operand to `jnp.matmul`, so `rnn_lm_apply`,
`T.prefill` and `T.decode_step` run unmodified against either a training
tree or an exported packed tree."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.qtensor import QTensor
from repro.core.quantize import BINARY_GROUP, TERNARY_GROUP
from repro.kernels import dispatch
from repro.kernels import packed_matmul as PK

Array = jax.Array


def _pad_to(x: Array, m: int, axis: int) -> Array:
    r = x.shape[axis] % m
    if r == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, m - r)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("k", "mode", "interpret"))
def packed_matmul(x: Array, wp: Array, k: int, alpha=1.0, *, mode: str = "ternary",
                  interpret: Optional[bool] = None) -> Array:
    """y = alpha * (x @ unpack(wp)).  x: (..., K); wp: (K/G, N) uint32.

    Leading batch dims are flattened into M; M/N/K padded to block multiples.
    Decode shapes (M <= 8 rows) route to the accumulation-only GEMV kernel
    (`packed_gemv` — zero weight-path multiplies); larger M keeps the MXU
    decode-tile path, which is the right engine for prefill GEMM.
    """
    group = TERNARY_GROUP if mode == "ternary" else BINARY_GROUP
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = wp.shape[1]
    xm = x.reshape(-1, K)
    M = xm.shape[0]

    if M <= 8:
        xm = _pad_to(_pad_to(xm.astype(jnp.float32), 8, 0), group, 1)
        kp = max(xm.shape[1], wp.shape[0] * group)
        xm = jnp.pad(xm, ((0, 0), (0, kp - xm.shape[1])))
        wpp = jnp.pad(wp, ((0, kp // group - wp.shape[0]), (0, -N % 128)))
        y = PK.packed_gemv(xm, wpp, kp, mode=mode, interpret=interpret)
    else:
        bm = 128 if M >= 128 else 8
        bn = 128
        bk = 256 if K % 256 == 0 else group * 8
        xm = _pad_to(_pad_to(xm, bm, 0), bk, 1)
        wpp = _pad_to(_pad_to(wp, bk // group, 0), bn, 1)
        y = PK.packed_matmul(xm, wpp, xm.shape[1], mode=mode,
                             block=(bm, bn, bk), interpret=interpret)
    y = y[:M, :N] * jnp.asarray(alpha, jnp.float32)
    return y.reshape(*lead, N)


@functools.partial(jax.jit, static_argnames=("mode", "interpret"))
def quantize_pack(w: Array, u: Array, alpha, *, mode: str = "ternary",
                  interpret: Optional[bool] = None) -> Array:
    """Fused stochastic quantize (paper Eq. 4-6) + bit-pack.  w: (K, N) with
    K % GROUP == 0 (weights in this framework are 128-aligned)."""
    group = TERNARY_GROUP if mode == "ternary" else BINARY_GROUP
    K, N = w.shape
    bk = min(256, K) if K % 256 == 0 or K <= 256 else group * 8
    while K % bk:
        bk //= 2
    bk = max(bk, group)
    bn = min(256, N)
    while N % bn:
        bn //= 2
    return PK.quantize_pack(w.astype(jnp.float32), u.astype(jnp.float32),
                            alpha, mode=mode, block=(bk, bn),
                            interpret=interpret)


# ---------------------------------------------------------------------------
# qmatmul: the one matmul entry for fp AND packed weights
# ---------------------------------------------------------------------------


def qmatmul(x: Array, w, *, interpret: Optional[bool] = None) -> Array:
    """y = x @ w for fp `w`, or the Pallas packed matmul for `QTensor` w.

    x: (..., K).  A stacked QTensor (codes (L, ..., K/G, N)) is applied
    per-matrix: x's leading axes must start with the same L (expert / layer
    batch), and the L slices run as an unrolled loop (L is small and static —
    experts per layer — and this keeps us off pallas_call batching rules).

    Output dtype follows x (the activation compute dtype); the packed kernel
    accumulates in fp32 either way.

    Sharded codes (mesh serving): the dense-fallback branch accepts SPMD-
    sharded QTensors as-is.  Column-parallel codes (last axis on 'model')
    flow through `dequantize` untouched — its unpack reshapes only the
    packed-row axis, so the column sharding propagates to the dense weight
    and the dot computes each output shard locally (xW sharded exactly like
    a dense column-parallel matmul).  Row-parallel codes partition the
    contraction dim and the dot's psum does the rest; `serve_param_shardings`
    only emits that layout when the shard boundary cannot fall inside a pack
    word or the dequantize pad-slice (`qtensor_pspecs`).  The Pallas branch
    is a single-device launch and must NOT see sharded operands — mesh
    engines gate on `dispatch.packed_pallas_active` before construction.
    """
    if not isinstance(w, QTensor):
        return x @ w
    if w.codes.ndim > 2:
        L = w.codes.shape[0]
        if x.shape[0] != L:
            raise ValueError(
                f"stacked QTensor with {L} matrices needs x batched the same "
                f"way, got x {x.shape}")
        sl = lambda i: jax.tree.map(lambda c: c[i], w)
        return jnp.stack([qmatmul(x[i], sl(i), interpret=interpret)
                          for i in range(L)])
    if x.shape[-1] != w.k:
        raise ValueError(f"qmatmul contraction mismatch: x {x.shape} vs "
                         f"QTensor k={w.k}")
    if not dispatch.use_pallas(interpret):
        # backend-honest CPU fallback (kernels/dispatch.py): dequantize and
        # run a dense matmul instead of emulating the Pallas kernel in
        # interpret mode.  Memory stays the packed codes; serving paths that
        # hit this every step cache the dense weight once per session
        # instead (rnn_decode_tables(dense=True)).  interpret=True is the
        # parity-test opt-in that still forces the emulated kernel here.
        y = jnp.dot(x.astype(jnp.float32), w.dequantize(jnp.float32))
        return y.astype(x.dtype)
    # zero-pad activations to the codes' K coverage: pad lanes multiply
    # zeros, so pack-time pad codes contribute exactly nothing.
    kp = w.codes.shape[-2] * w.group
    if kp != w.k:
        x_in = _pad_to(x.reshape(-1, w.k), w.group, 1).reshape(
            x.shape[:-1] + (kp,))
    else:
        x_in = x
    y = packed_matmul(x_in, w.codes, kp, w.alpha, mode=w.mode,
                      interpret=interpret)
    if w.scale is not None:
        y = y * w.scale
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# fused whole-tick recurrent decode (kernels/decode_step.py)
# ---------------------------------------------------------------------------


def prepare_gate_codes(qt: QTensor, n_gates: int) -> Array:
    """Gate-align a packed recurrent weight for the fused decode kernel.

    `qt` packs wh (H, n_gates*H).  Each gate's N columns are sliced out,
    padded to the 128-lane tile Hp (so gate boundaries stay tile-aligned in
    the kernel), the K code rows are padded to Hp/GROUP, and the gates are
    stacked: (n_gates, Hp/G, Hp) uint32.  Pad K codes are harmless — the
    matching activation lanes are zero-padded.  Done ONCE per serving
    session (serve/recurrent.py caches the result in the decode tables)."""
    from repro.kernels.decode_step import BN_TILE

    if qt.scale is not None:
        # the fused kernel folds only alpha * BN-affine into its scale; a
        # per-channel QTensor scale would be silently dropped
        raise ValueError("fused decode does not support channel-scaled "
                         "QTensors (RNN export packs scale-free weights); "
                         "use the unfused path")
    kg, N = qt.codes.shape
    H = N // n_gates
    if H * n_gates != N or qt.k != H:
        raise ValueError(f"expected a square-per-gate (H, {n_gates}*H) "
                         f"recurrent weight, got k={qt.k}, N={N}")
    hp = -(-max(H, 1) // BN_TILE) * BN_TILE
    gates = [jnp.pad(qt.codes[:, i * H:(i + 1) * H],
                     ((0, hp // qt.group - kg), (0, hp - H)))
             for i in range(n_gates)]
    return jnp.stack(gates)


# padded head weight bytes the fused tick will keep in VMEM alongside the
# codes; beyond this the head runs as one XLA dot outside the launch
HEAD_VMEM_BYTES = 4 * 1024 * 1024


def fused_decode_tick(tok: Array, h: Array, c: Array, tick: dict, *,
                      cell: str, mode: str, vocab: int,
                      live: Optional[Array] = None,
                      interpret: Optional[bool] = None):
    """One whole-model decode tick in a SINGLE Pallas launch.

    tok: (B,) int32; h/c: (L, B, H) carried state; `tick` is the stacked
    artifact `core.bnlstm.rnn_decode_tables` builds once per session
    (arrays only — it travels through jits as a pytree argument):

      rows0            (vocab, g*H)  layer-0 token rows, BN + bias folded
      codes_h          (L, g, Hp/G, Hp)   gate-aligned packed wh codes
      codes_x          (max(L-1,1), g, Hp/G, Hp)  packed wx codes, l >= 1
      scale_h/shift_h  (L, g, Hp)    h-side BN affine, alpha folded in scale
      scale_x/shift_x  (like codes_x's lead, g, Hp)  x-side BN + bias fold
      scale_c/shift_c  (L, 1, Hp)    cell-norm affine
      ws/bs            (Hp, Vp) / (1, Vp)  fp head, bias pads = finfo.min

    The layer-0 gather runs outside (an XLA gather is not a launch); the
    kernel scans the layers with h/c in VMEM, runs the accumulation-only
    GEMVs, and — when the padded head fits the VMEM budget — the logits
    head and greedy argmax too.  `live` (B,) bool freezes dead rows
    in-kernel, bit-for-bit.

    Returns (logits (B, vocab), h', c', greedy (B,) int32).
    """
    from repro.kernels import decode_step as DK

    L, B, H = h.shape
    codes_h = tick["codes_h"]
    g, hp = codes_h.shape[1], codes_h.shape[-1]
    bp = -(-max(B, 1) // 8) * 8
    f32 = jnp.float32

    rows = jnp.take(tick["rows0"], tok, axis=0).astype(f32)     # (B, g*H)
    ax0 = jnp.pad(rows.reshape(B, g, H),
                  ((0, bp - B), (0, 0), (0, hp - H)))
    pad_state = lambda a: jnp.pad(a.astype(f32),
                                  ((0, 0), (0, bp - B), (0, hp - H)))
    if live is None:
        live_m = jnp.ones((bp, hp), f32)
    else:  # pad rows 0: they select their (zero) previous state
        live_m = jnp.pad(jnp.broadcast_to(live.astype(f32)[:, None], (B, hp)),
                         ((0, bp - B), (0, 0)))

    ws, bs = tick["ws"], tick["bs"]
    vp = ws.shape[1]
    with_head = (hp * vp + 2 * bp * vp) * 4 <= HEAD_VMEM_BYTES
    out = DK.fused_tick(ax0, pad_state(h), pad_state(c), live_m, codes_h,
                        tick["codes_x"], tick["scale_h"], tick["shift_h"],
                        tick["scale_x"], tick["shift_x"], tick["scale_c"],
                        tick["shift_c"], ws if with_head else None,
                        bs if with_head else None, cell=cell, mode=mode,
                        interpret=interpret)
    if with_head:
        hn, cn, lg, tk = out
        logits = lg[:B, :vocab]
        greedy = tk[:B, 0]
    else:  # head too big for VMEM: one XLA dot outside, still one launch
        hn, cn = out
        lg = jnp.dot(hn[-1], ws, preferred_element_type=f32) + bs
        logits = lg[:B, :vocab]
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return (logits.astype(h.dtype), hn[:, :B, :H].astype(h.dtype),
            cn[:, :B, :H].astype(h.dtype), greedy)
