"""jit'd public wrappers around the Pallas kernels: padding to block
multiples, alpha scaling, dtype handling, and `qmatmul` — the single
dispatch entry every matmul call site in the model code goes through.

`qmatmul(x, w)` routes a `QTensor` operand (core/qtensor.py) to the Pallas
packed kernel and an fp operand to `jnp.matmul`, so `rnn_lm_apply`,
`T.prefill` and `T.decode_step` run unmodified against either a training
tree or an exported packed tree."""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.qtensor import QTensor
from repro.core.quantize import BINARY_GROUP, TERNARY_GROUP
from repro.kernels import packed_matmul as PK

Array = jax.Array


def _pad_to(x: Array, m: int, axis: int) -> Array:
    r = x.shape[axis] % m
    if r == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, m - r)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("k", "mode", "interpret"))
def packed_matmul(x: Array, wp: Array, k: int, alpha=1.0, *, mode: str = "ternary",
                  interpret: Optional[bool] = None) -> Array:
    """y = alpha * (x @ unpack(wp)).  x: (..., K); wp: (K/G, N) uint32.

    Leading batch dims are flattened into M; M/N/K padded to block multiples.
    """
    group = TERNARY_GROUP if mode == "ternary" else BINARY_GROUP
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = wp.shape[1]
    xm = x.reshape(-1, K)
    M = xm.shape[0]

    bm = 128 if M >= 128 else 8
    bn = 128
    bk = 256 if K % 256 == 0 else group * 8
    xm = _pad_to(_pad_to(xm, bm, 0), bk, 1)
    wpp = _pad_to(_pad_to(wp, bk // group, 0), bn, 1)
    y = PK.packed_matmul(xm, wpp, xm.shape[1], mode=mode,
                         block=(bm, bn, bk), interpret=interpret)
    y = y[:M, :N] * jnp.asarray(alpha, jnp.float32)
    return y.reshape(*lead, N)


@functools.partial(jax.jit, static_argnames=("mode", "interpret"))
def quantize_pack(w: Array, u: Array, alpha, *, mode: str = "ternary",
                  interpret: Optional[bool] = None) -> Array:
    """Fused stochastic quantize (paper Eq. 4-6) + bit-pack.  w: (K, N) with
    K % GROUP == 0 (weights in this framework are 128-aligned)."""
    group = TERNARY_GROUP if mode == "ternary" else BINARY_GROUP
    K, N = w.shape
    bk = min(256, K) if K % 256 == 0 or K <= 256 else group * 8
    while K % bk:
        bk //= 2
    bk = max(bk, group)
    bn = min(256, N)
    while N % bn:
        bn //= 2
    return PK.quantize_pack(w.astype(jnp.float32), u.astype(jnp.float32),
                            alpha, mode=mode, block=(bk, bn),
                            interpret=interpret)


# ---------------------------------------------------------------------------
# qmatmul: the one matmul entry for fp AND packed weights
# ---------------------------------------------------------------------------


def qmatmul(x: Array, w, *, interpret: Optional[bool] = None) -> Array:
    """y = x @ w for fp `w`, or the Pallas packed matmul for `QTensor` w.

    x: (..., K).  A stacked QTensor (codes (L, ..., K/G, N)) is applied
    per-matrix: x's leading axes must start with the same L (expert / layer
    batch), and the L slices run as an unrolled loop (L is small and static —
    experts per layer — and this keeps us off pallas_call batching rules).

    Output dtype follows x (the activation compute dtype); the packed kernel
    accumulates in fp32 either way.
    """
    if not isinstance(w, QTensor):
        return x @ w
    if w.codes.ndim > 2:
        L = w.codes.shape[0]
        if x.shape[0] != L:
            raise ValueError(
                f"stacked QTensor with {L} matrices needs x batched the same "
                f"way, got x {x.shape}")
        sl = lambda i: jax.tree.map(lambda c: c[i], w)
        return jnp.stack([qmatmul(x[i], sl(i), interpret=interpret)
                          for i in range(L)])
    if x.shape[-1] != w.k:
        raise ValueError(f"qmatmul contraction mismatch: x {x.shape} vs "
                         f"QTensor k={w.k}")
    # zero-pad activations to the codes' K coverage: pad lanes multiply
    # zeros, so pack-time pad codes contribute exactly nothing.
    kp = w.codes.shape[-2] * w.group
    if kp != w.k:
        x_in = _pad_to(x.reshape(-1, w.k), w.group, 1).reshape(
            x.shape[:-1] + (kp,))
    else:
        x_in = x
    y = packed_matmul(x_in, w.codes, kp, w.alpha, mode=w.mode,
                      interpret=interpret)
    if w.scale is not None:
        y = y * w.scale
    return y.astype(x.dtype)


@dataclasses.dataclass
class PackedLinear:
    """Deprecated shim: a QTensor plus its qmatmul call.  Prefer building
    QTensors via `core.qtensor.export_packed` and calling `qmatmul`."""

    qt: QTensor

    @classmethod
    def from_master(cls, w: Array, alpha: float, mode: str,
                    scale: Optional[Array] = None) -> "PackedLinear":
        return cls(QTensor.from_master(w, mode, alpha, scale=scale))

    def __call__(self, x: Array, *, interpret: Optional[bool] = None) -> Array:
        return qmatmul(x, self.qt, interpret=interpret)

    @property
    def wp(self) -> Array:
        return self.qt.codes

    @property
    def k(self) -> int:
        return self.qt.k

    @property
    def alpha(self) -> float:
        return self.qt.alpha

    @property
    def mode(self) -> str:
        return self.qt.mode

    @property
    def nbytes(self) -> int:
        return self.qt.nbytes
