"""Backend-honest kernel dispatch (DESIGN.md §11).

One policy decides, per backend, how a packed-weight op actually runs — so
recorded numbers always measure real work and CPU never silently executes
interpret-mode Pallas emulation in a serving path:

    backend   packed matmul / decode tick        interpret-mode Pallas
    -------   -------------------------------    ----------------------
    tpu/gpu   compiled Pallas kernel             never
    cpu       dense fp fallback (weights are     opt-in ONLY (parity
              dequantized ONCE per session;      tests pass
              memory stays the packed codes)     interpret=True)

Every entry that used to make this call locally (`rnn_decode_tables(dense=)`,
`qmatmul`, the decode-step wrappers) now asks this module.  The convention
shared by all of them: an `interpret`/`dense` argument of None means "do the
honest thing for this backend"; an explicit value is a caller opt-in (the
parity suites run the interpret kernels against the dense fallback on CPU).

The module also owns two proof utilities the tier-1 tests assert on:

  * a TRACE-TIME launch counter — every `pl.pallas_call` wrapper in this
    package bumps it once per launch it traces, so "the decode tick is ONE
    fused launch" is counted the same way the engine counts `tick_traces`,
    not inferred from profiles;
  * `assert_accumulation_only` — walks a function's jaxpr (recursively
    through scan/cond/pjit sub-jaxprs) and fails if any `mul`/`dot_general`
    survives, the static form of the paper's multiply-free weight path.
"""
from __future__ import annotations

from typing import Optional

import jax

try:  # jax moved core types under jax.extend in newer releases
    from jax.extend.core import ClosedJaxpr, Jaxpr
except ImportError:  # pragma: no cover - older jax
    from jax.core import ClosedJaxpr, Jaxpr


def backend() -> str:
    """The platform actually executing jitted code ('cpu', 'tpu', 'gpu')."""
    return jax.default_backend()


def prefer_dense(dense: Optional[bool] = None) -> bool:
    """Should a serving session expand packed weights into dense fp tables?

    None -> the backend policy: True on CPU (packed Pallas would only run
    emulated there), False on real accelerators (the fused packed kernels
    are the whole point).  An explicit bool is a caller override.
    """
    if dense is not None:
        return dense
    return backend() == "cpu"


def use_pallas(interpret: Optional[bool] = None) -> bool:
    """Should this op run a Pallas kernel at all?

    False only on CPU with no explicit `interpret` request — that is the
    dense-fallback case.  `interpret=True` is the parity-test opt-in
    (emulated kernel, real kernel semantics); on tpu/gpu the compiled
    kernel always runs.
    """
    if interpret is not None:
        return True
    return backend() != "cpu"


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Interpret flag for a Pallas call that IS going to run: None means
    'emulate on CPU, compile elsewhere' (direct kernel entries keep working
    on CPU for tests that did not pass an explicit flag)."""
    if interpret is not None:
        return interpret
    return backend() == "cpu"


# ---------------------------------------------------------------------------
# trace-time launch counter
# ---------------------------------------------------------------------------

_launches = 0


def count_launch(name: str) -> None:
    """Called by every pallas_call wrapper in kernels/ at TRACE time, once
    per launch it emits into the computation being traced.  Like the
    engine's `tick_traces`, the count is a property of the traced program,
    not of executions — a jitted tick that traces N launches dispatches N
    kernels every call thereafter."""
    del name
    global _launches
    _launches += 1


def launch_count() -> int:
    """Monotonic total of Pallas launches traced so far; callers diff it
    around a trace to count launches-per-tick."""
    return _launches


def traced_launches(fn, *args, **kwargs) -> int:
    """Launches the jitted form of `fn(*args)` dispatches per call: trace it
    once (abstractly — nothing executes) and diff the counter."""
    before = launch_count()
    jax.eval_shape(lambda *a: fn(*a, **kwargs), *args)
    return launch_count() - before


# ---------------------------------------------------------------------------
# static mul-freeness proof
# ---------------------------------------------------------------------------

_MULTIPLY_PRIMS = ("mul", "dot_general", "conv_general_dilated")


def _sub_jaxprs(v):
    if isinstance(v, ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, Jaxpr):
        yield v
    elif isinstance(v, (list, tuple)):
        for x in v:
            yield from _sub_jaxprs(x)


def _multiply_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in _MULTIPLY_PRIMS:
            yield eqn
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                yield from _multiply_eqns(sub)


def assert_accumulation_only(fn, *args, **kwargs):
    """Statically prove `fn(*args, **kwargs)` contains NO multiplies.

    Walks the jaxpr (recursing into scan/cond/pjit bodies) and raises
    AssertionError listing every `mul`/`dot_general`/conv equation found.
    The packed GEMV path is asserted with this in tier-1: the decoded
    weights are consumed by select/add/subtract ONLY — the paper's
    replace-every-MAC-with-an-accumulation claim, as a compiler fact."""
    import functools

    closed = jax.make_jaxpr(functools.partial(fn, **kwargs))(*args)
    bad = list(_multiply_eqns(closed.jaxpr))
    if bad:
        lines = "\n  ".join(str(e) for e in bad[:8])
        raise AssertionError(
            f"{len(bad)} multiply op(s) in supposedly accumulation-only "
            f"path:\n  {lines}")
    return closed


# ---------------------------------------------------------------------------
# mesh-serving proofs and gates
# ---------------------------------------------------------------------------

_COLLECTIVES = ("all-reduce", "all-gather", "all-to-all",
                "collective-permute", "reduce-scatter")


def collective_ops(hlo_text: str) -> list:
    """Collective-communication ops named in compiled HLO text.

    The third proof utility (after the launch counter and mul-freeness):
    the mesh serving tests compile the data-sharded decode tick and assert
    this returns [] — every op of the tick is shard-local, so adding slot
    shards never adds wire traffic (DESIGN.md §12).  Tensor-parallel ticks
    legitimately contain reductions and are NOT asserted collective-free.
    """
    low = hlo_text.lower()
    return [c for c in _COLLECTIVES if c in low]


def packed_pallas_active(tree) -> bool:
    """True when serving `tree` on this backend would dispatch the packed
    Pallas kernels (QTensor leaves present and the backend runs Pallas).

    A mesh-sharded engine must refuse that combination today: pallas_call
    is a single-device launch, so running it over a sharded slot pool or
    sharded codes needs a shard_map port (ROADMAP).  On CPU the same tree
    serves through the compiled dense fallback, whose dequantize + dot
    partition cleanly under SPMD — which is what makes the whole mesh
    story CI-provable on host devices."""
    from repro.core.qtensor import is_qtensor
    if not use_pallas(None):
        return False
    return any(is_qtensor(l) for l in
               jax.tree_util.tree_leaves(tree, is_leaf=is_qtensor))
