"""Pallas TPU kernels: matmul against 2-bit (ternary) / 1-bit (binary) packed
weights, and fused stochastic quantize+pack.

This is the TPU-native translation of the paper's MAC-free ASIC engine
(DESIGN.md §2): the ±1/0 weights live PACKED in HBM (16x / 32x fewer weight
bytes than fp32), are decoded to bf16 inside VMEM by the VPU (shift/and/
select — no cross-lane work since packing is along the contraction axis), and
the MXU consumes the decoded tile.  Decode-bound GEMV/GEMM arithmetic
intensity rises by the packing factor, which is exactly where the paper's
"12x memory bandwidth" claim lands on a TPU.

Tiling: grid (M/bm, N/bn, K/bk), K innermost so the fp32 VMEM accumulator
carries across the K loop; all dims MXU-aligned (multiples of 8/128).  The
packed operand's K axis is bk/GROUP uint32 rows per tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.quantize import BINARY_GROUP, TERNARY_GROUP
from repro.kernels import dispatch

Array = jax.Array


def _unpack_ternary_tile(packed: Array, bk: int) -> Array:
    """(bk/16, bn) uint32 -> (bk, bn) float32 in {-1, 0, +1}."""
    shifts = (2 * jnp.arange(TERNARY_GROUP, dtype=jnp.uint32))[None, :, None]
    codes = (packed[:, None, :] >> shifts) & jnp.uint32(3)
    vals = jnp.where(codes == 1, 1.0, jnp.where(codes == 3, -1.0, 0.0))
    return vals.reshape(bk, packed.shape[-1])


def _unpack_binary_tile(packed: Array, bk: int) -> Array:
    """(bk/32, bn) uint32 -> (bk, bn) float32 in {-1, +1}."""
    shifts = jnp.arange(BINARY_GROUP, dtype=jnp.uint32)[None, :, None]
    bits = (packed[:, None, :] >> shifts) & jnp.uint32(1)
    vals = bits.astype(jnp.float32) * 2.0 - 1.0
    return vals.reshape(bk, packed.shape[-1])


def code_masks(packed: Array, *, mode: str) -> tuple[Array, Array]:
    """Decode packed codes to (plus, minus) BOOLEAN masks — no arithmetic on
    the weight values, ever.  packed: (K/G, N) uint32 -> two (K, N) bools.

    Ternary: plus where code==0b01, minus where code==0b11, neither for the
    zero code.  Binary: plus where bit==1, minus where bit==0.  The masks
    are what the accumulation-only GEMV selects activations through; the
    ±1/0 weight VALUES never materialize as floats on this path.
    """
    if mode == "ternary":
        # iota << 1, not 2*iota: even the shift table is mul-free so the
        # static accumulation-only assertion holds over the whole path (a
        # stepped arange would materialize a constant Pallas can't capture)
        shifts = (jnp.arange(TERNARY_GROUP, dtype=jnp.uint32)
                  << jnp.uint32(1))[None, :, None]
        codes = (packed[:, None, :] >> shifts) & jnp.uint32(3)
        k = packed.shape[0] * TERNARY_GROUP
        plus = (codes == 1).reshape(k, packed.shape[-1])
        minus = (codes == 3).reshape(k, packed.shape[-1])
        return plus, minus
    shifts = jnp.arange(BINARY_GROUP, dtype=jnp.uint32)[None, :, None]
    bits = (packed[:, None, :] >> shifts) & jnp.uint32(1)
    k = packed.shape[0] * BINARY_GROUP
    plus = (bits == 1).reshape(k, packed.shape[-1])
    return plus, jnp.logical_not(plus)


def accumulate_gemv(x: Array, packed: Array, *, mode: str) -> Array:
    """y = x @ unpack(packed) with ZERO multiplies — the paper's MAC-free
    inner loop (DESIGN.md §11).  x: (B, K) fp; packed: (K/G, N) uint32;
    returns (B, N) fp32.

    The decoded weight is never a float: codes become (plus, minus) boolean
    masks, each output column is `sum(select(plus, x, 0)) -
    sum(select(minus, x, 0))` — shift/and/compare/select/add only.  Tier-1
    asserts this statically (`dispatch.assert_accumulation_only`): the jaxpr
    contains no `mul`/`dot_general`.  B is a static Python loop: at decode
    B is the (padded) slot count, <= 8, and unrolling keeps every step a
    plain lane-wise select + row reduction the VPU streams.

    Binary pad safety: a ZERO pad code decodes to minus (−1), but pad
    activation lanes are zero-padded by every caller, so `select(minus, 0,
    0)` contributes exactly nothing — same invariant the MXU path relies
    on.
    """
    x = x.astype(jnp.float32)
    plus, minus = code_masks(packed, mode=mode)
    rows = []
    for b in range(x.shape[0]):
        xb = x[b, :, None]  # (K, 1) broadcasts across the N output columns
        t = jnp.where(plus, xb, 0.0) - jnp.where(minus, xb, 0.0)
        rows.append(jnp.sum(t, axis=0))
    return jnp.stack(rows)


def _gemv_kernel(x_ref, wp_ref, o_ref, *, mode: str):
    o_ref[...] = accumulate_gemv(x_ref[...], wp_ref[...], mode=mode)


def packed_gemv(x: Array, wp: Array, k: int, *, mode: str,
                block_n: int = 128, interpret: bool | None = None) -> Array:
    """Accumulation-only decode-shape matmul: x (Bp, K) with Bp <= 8, packed
    wp (K/G, N) -> (Bp, N) fp32, one launch, grid over N tiles.

    This is the mul-free sibling of `packed_matmul`: where the MXU path
    decodes codes to ±1 floats and feeds a dense dot (right for prefill
    GEMM, M large), this kernel selects/accumulates activations through the
    code masks — the arithmetic the paper's ASIC does.  `ops.packed_matmul`
    routes M <= 8 here and larger M to the MXU path."""
    group = TERNARY_GROUP if mode == "ternary" else BINARY_GROUP
    bp, K = x.shape
    N = wp.shape[1]
    if K != k or wp.shape[0] * group != K:
        raise ValueError(f"packed K mismatch: {wp.shape[0]}*{group} != {K}")
    if N % block_n:
        raise ValueError(f"N={N} must be a multiple of block_n={block_n}")
    interpret = dispatch.resolve_interpret(interpret)

    kernel = functools.partial(_gemv_kernel, mode=mode)
    dispatch.count_launch(f"{mode}_packed_gemv")
    return pl.pallas_call(
        kernel,
        grid=(N // block_n,),
        in_specs=[
            pl.BlockSpec((bp, K), lambda j: (0, 0)),
            pl.BlockSpec((K // group, block_n), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bp, block_n), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((bp, N), jnp.float32),
        interpret=interpret,
        name=f"{mode}_packed_gemv",
    )(x, wp)


def _matmul_kernel(x_ref, wp_ref, o_ref, acc_ref, *, bk: int, mode: str):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    unpack = _unpack_ternary_tile if mode == "ternary" else _unpack_binary_tile
    w = unpack(wp_ref[...], bk).astype(x_ref.dtype)
    acc_ref[...] += jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def packed_matmul(x: Array, wp: Array, k: int, *, mode: str,
                  block: tuple[int, int, int] = (128, 128, 256),
                  interpret: bool | None = None) -> Array:
    """x: (M, K) fp; wp: (K/G, N) uint32 packed -> (M, N) fp32 (unscaled).

    M, N, K must already be multiples of the block dims (ops.py pads).
    """
    group = TERNARY_GROUP if mode == "ternary" else BINARY_GROUP
    M, K = x.shape
    N = wp.shape[1]
    if K != k or wp.shape[0] * group != K:
        raise ValueError(f"packed K mismatch: {wp.shape[0]}*{group} != {K}")
    bm, bn, bk = block
    if M % bm or N % bn or K % bk or bk % group:
        raise ValueError(f"blocks {block} must divide {(M, N, K)} (bk % {group} == 0)")
    interpret = dispatch.resolve_interpret(interpret)

    kernel = functools.partial(_matmul_kernel, bk=bk, mode=mode)
    dispatch.count_launch(f"{mode}_packed_matmul")
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, K // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // group, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        name=f"{mode}_packed_matmul",
    )(x, wp)


# ---------------------------------------------------------------------------
# fused stochastic quantize + pack
# ---------------------------------------------------------------------------


def _qpack_kernel(w_ref, u_ref, alpha_ref, o_ref, *, mode: str):
    a = alpha_ref[0, 0]
    wn = jnp.clip(w_ref[...] / a, -1.0, 1.0)
    bk, bn = wn.shape
    if mode == "ternary":
        nz = u_ref[...] < jnp.abs(wn)
        t = jnp.where(nz, jnp.sign(wn), 0.0)
        codes = jnp.where(t > 0, 1, jnp.where(t < 0, 3, 0)).astype(jnp.uint32)
        g = TERNARY_GROUP
        shifts = (2 * jnp.arange(g, dtype=jnp.uint32))[None, :, None]
    else:
        p_one = (wn + 1.0) * 0.5
        codes = (u_ref[...] < p_one).astype(jnp.uint32)
        g = BINARY_GROUP
        shifts = jnp.arange(g, dtype=jnp.uint32)[None, :, None]
    c = codes.reshape(bk // g, g, bn)
    o_ref[...] = jnp.sum(c << shifts, axis=1, dtype=jnp.uint32)


def quantize_pack(w: Array, u: Array, alpha, *, mode: str,
                  block: tuple[int, int] = (256, 256),
                  interpret: bool | None = None) -> Array:
    """Fused Eq.(4-6) sampling + bit-packing.  w, u: (K, N); returns packed
    uint32 (K/G, N).  Noise is an explicit operand (Pallas-portable PRNG)."""
    group = TERNARY_GROUP if mode == "ternary" else BINARY_GROUP
    K, N = w.shape
    bk, bn = block
    if K % bk or N % bn or bk % group:
        raise ValueError(f"blocks {block} must divide {(K, N)} (bk % {group} == 0)")
    interpret = dispatch.resolve_interpret(interpret)
    alpha = jnp.asarray(alpha, jnp.float32).reshape(1, 1)

    kernel = functools.partial(_qpack_kernel, mode=mode)
    dispatch.count_launch(f"{mode}_quantize_pack")
    return pl.pallas_call(
        kernel,
        grid=(K // bk, N // bn),
        in_specs=[
            pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bk // group, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((K // group, N), jnp.uint32),
        interpret=interpret,
        name=f"{mode}_quantize_pack",
    )(w, u, alpha)
