"""Pallas TPU kernels: matmul against 2-bit (ternary) / 1-bit (binary) packed
weights, and fused stochastic quantize+pack.

This is the TPU-native translation of the paper's MAC-free ASIC engine
(DESIGN.md §2): the ±1/0 weights live PACKED in HBM (16x / 32x fewer weight
bytes than fp32), are decoded to bf16 inside VMEM by the VPU (shift/and/
select — no cross-lane work since packing is along the contraction axis), and
the MXU consumes the decoded tile.  Decode-bound GEMV/GEMM arithmetic
intensity rises by the packing factor, which is exactly where the paper's
"12x memory bandwidth" claim lands on a TPU.

Tiling: grid (M/bm, N/bn, K/bk), K innermost so the fp32 VMEM accumulator
carries across the K loop; all dims MXU-aligned (multiples of 8/128).  The
packed operand's K axis is bk/GROUP uint32 rows per tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.quantize import BINARY_GROUP, TERNARY_GROUP

Array = jax.Array


def _unpack_ternary_tile(packed: Array, bk: int) -> Array:
    """(bk/16, bn) uint32 -> (bk, bn) float32 in {-1, 0, +1}."""
    shifts = (2 * jnp.arange(TERNARY_GROUP, dtype=jnp.uint32))[None, :, None]
    codes = (packed[:, None, :] >> shifts) & jnp.uint32(3)
    vals = jnp.where(codes == 1, 1.0, jnp.where(codes == 3, -1.0, 0.0))
    return vals.reshape(bk, packed.shape[-1])


def _unpack_binary_tile(packed: Array, bk: int) -> Array:
    """(bk/32, bn) uint32 -> (bk, bn) float32 in {-1, +1}."""
    shifts = jnp.arange(BINARY_GROUP, dtype=jnp.uint32)[None, :, None]
    bits = (packed[:, None, :] >> shifts) & jnp.uint32(1)
    vals = bits.astype(jnp.float32) * 2.0 - 1.0
    return vals.reshape(bk, packed.shape[-1])


def _matmul_kernel(x_ref, wp_ref, o_ref, acc_ref, *, bk: int, mode: str):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    unpack = _unpack_ternary_tile if mode == "ternary" else _unpack_binary_tile
    w = unpack(wp_ref[...], bk).astype(x_ref.dtype)
    acc_ref[...] += jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def packed_matmul(x: Array, wp: Array, k: int, *, mode: str,
                  block: tuple[int, int, int] = (128, 128, 256),
                  interpret: bool | None = None) -> Array:
    """x: (M, K) fp; wp: (K/G, N) uint32 packed -> (M, N) fp32 (unscaled).

    M, N, K must already be multiples of the block dims (ops.py pads).
    """
    group = TERNARY_GROUP if mode == "ternary" else BINARY_GROUP
    M, K = x.shape
    N = wp.shape[1]
    if K != k or wp.shape[0] * group != K:
        raise ValueError(f"packed K mismatch: {wp.shape[0]}*{group} != {K}")
    bm, bn, bk = block
    if M % bm or N % bn or K % bk or bk % group:
        raise ValueError(f"blocks {block} must divide {(M, N, K)} (bk % {group} == 0)")
    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    kernel = functools.partial(_matmul_kernel, bk=bk, mode=mode)
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, K // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // group, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        name=f"{mode}_packed_matmul",
    )(x, wp)


# ---------------------------------------------------------------------------
# fused stochastic quantize + pack
# ---------------------------------------------------------------------------


def _qpack_kernel(w_ref, u_ref, alpha_ref, o_ref, *, mode: str):
    a = alpha_ref[0, 0]
    wn = jnp.clip(w_ref[...] / a, -1.0, 1.0)
    bk, bn = wn.shape
    if mode == "ternary":
        nz = u_ref[...] < jnp.abs(wn)
        t = jnp.where(nz, jnp.sign(wn), 0.0)
        codes = jnp.where(t > 0, 1, jnp.where(t < 0, 3, 0)).astype(jnp.uint32)
        g = TERNARY_GROUP
        shifts = (2 * jnp.arange(g, dtype=jnp.uint32))[None, :, None]
    else:
        p_one = (wn + 1.0) * 0.5
        codes = (u_ref[...] < p_one).astype(jnp.uint32)
        g = BINARY_GROUP
        shifts = jnp.arange(g, dtype=jnp.uint32)[None, :, None]
    c = codes.reshape(bk // g, g, bn)
    o_ref[...] = jnp.sum(c << shifts, axis=1, dtype=jnp.uint32)


def quantize_pack(w: Array, u: Array, alpha, *, mode: str,
                  block: tuple[int, int] = (256, 256),
                  interpret: bool | None = None) -> Array:
    """Fused Eq.(4-6) sampling + bit-packing.  w, u: (K, N); returns packed
    uint32 (K/G, N).  Noise is an explicit operand (Pallas-portable PRNG)."""
    group = TERNARY_GROUP if mode == "ternary" else BINARY_GROUP
    K, N = w.shape
    bk, bn = block
    if K % bk or N % bn or bk % group:
        raise ValueError(f"blocks {block} must divide {(K, N)} (bk % {group} == 0)")
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    alpha = jnp.asarray(alpha, jnp.float32).reshape(1, 1)

    kernel = functools.partial(_qpack_kernel, mode=mode)
    return pl.pallas_call(
        kernel,
        grid=(K // bk, N // bn),
        in_specs=[
            pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bk // group, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((K // group, N), jnp.uint32),
        interpret=interpret,
        name=f"{mode}_quantize_pack",
    )(w, u, alpha)
