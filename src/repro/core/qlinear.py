"""The paper's technique as a composable layer for arbitrary matmul stacks.

Which leaves quantize is decided by an explicit `QuantPolicy` resolved from
the spec (`spec.policy()`, core/quantize.py) — fnmatch globs over leaf names
and tree paths, defaulting to the repo convention of capital-'W' matmul
weights.  Everything the policy rejects (embeddings, norms, biases, routers,
decay vectors, BN/scale parameters) stays full precision — mirroring the
paper's own split (Algorithm 1 quantizes the eight recurrent matrices and
keeps biases/BN/softmax-classifier fp).

`quantize_tree(params, spec, rng)` quantizes every policy-matching leaf ONCE
per forward pass (paper Algorithm 1 lines 2-6), with straight-through
gradients to the fp master leaves.  Stacked per-layer weights (leading scan
dimension) are quantized in one shot, so the sampling sits OUTSIDE `lax.scan`
exactly like the paper samples outside the time loop.  Already-exported
`QTensor` leaves (core/qtensor.py) pass through untouched, so the same model
code serves packed weights.

For the transformer pool, the BN of Eq. (7) is adapted to a learnable
per-output-channel scale (`norm='channel'`): companion leaves named
's<wname>' created at init and applied by `scaled()` at the call site.
See DESIGN.md §2 for why batch statistics do not transfer to serving/TP.
"""
from __future__ import annotations

import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import quantize as Q
from repro.core.qtensor import is_qtensor
from repro.core.quantize import leaf_alpha  # noqa: F401  (re-export)
from repro.runtime import constrain_param

Array = jax.Array


def is_quantizable(path_key: str, spec: Optional[Q.QuantSpec] = None) -> bool:
    """Thin wrapper over the spec's QuantPolicy (kept for callers that only
    have a leaf name; prefer `spec.policy().matches(path, leaf)`)."""
    spec = spec if spec is not None else Q.QuantSpec()
    return spec.policy().matches_name(path_key)


_path_str = Q.path_str  # canonical leaf naming shared with policy + export


def quantize_tree(params: Any, spec: Q.QuantSpec, rng: Optional[Array],
                  compute_dtype=None) -> Any:
    """Quantize every policy-matching leaf (STE); pass everything else through.

    `compute_dtype` additionally casts the (quantized or fp) matmul weights
    to the model's compute precision (bf16 on TPU) AFTER quantization — the
    master weights and the STE path stay fp32, matching mixed-precision
    practice and keeping matmuls on the MXU fast path.

    Already-packed `QTensor` leaves (an exported serving tree) pass through
    verbatim — they are consumed packed by `kernels.ops.qmatmul`.
    """
    policy = spec.policy()

    def f(path, leaf):
        if is_qtensor(leaf):
            return leaf
        name = _path_str(path)
        if not policy.matches(path, leaf):
            return leaf

        def cast(w):
            w = w.astype(compute_dtype) if compute_dtype is not None else w
            # keep quantize+cast shard-local: the downstream all-gather then
            # moves bf16 quantized values, not fp32 masters
            return constrain_param(path, leaf, w)

        def packed_roundtrip(q, alpha):
            """quantize -> PACK (shard-local) -> gather uint32 codes over the
            FSDP axes -> unpack on-chip.  Semantically identity on q; the
            SPMD boundary lands on the 2-bit/1-bit codes (16x/32x fewer wire
            bytes).  Sits inside stop_gradient via ste(), so no bwd bit ops."""
            group = Q.TERNARY_GROUP if spec.mode == "ternary" else Q.BINARY_GROUP
            K, N = q.shape[-2], q.shape[-1]
            if K % group:
                return cast(q)
            lead = q.shape[:-2]
            qs = jax.lax.stop_gradient(q).reshape((-1, K, N)) / alpha
            pack = Q.pack_ternary if spec.mode == "ternary" else Q.pack_binary
            unpack = Q.unpack_ternary if spec.mode == "ternary" else Q.unpack_binary
            packed = jax.vmap(pack)(qs)
            packed = packed.reshape(lead + (K // group, N))
            packed = constrain_param(path, leaf, packed)
            codes = packed.reshape((-1, K // group, N))
            wq = jax.vmap(lambda c: unpack(c, K))(codes).reshape(lead + (K, N))
            wq = (alpha * wq)
            if compute_dtype is not None:
                wq = wq.astype(compute_dtype)
            # the unpacked copy takes the COMPUTE layout (consumer's view);
            # every reshard from the storage layout happens on the codes
            wq = constrain_param(path, leaf, wq, drop_axes=("data", "pod"),
                                 kind="compute")
            return Q.ste(leaf, wq)

        def finish(q_with_ste, alpha):
            if spec.packed_comms:
                return packed_roundtrip(q_with_ste, alpha)
            return cast(q_with_ste)

        if not spec.enabled:
            return cast(leaf)
        alpha = leaf_alpha(leaf.shape)
        if spec.mode in ("binary", "ternary") and spec.stochastic:
            if rng is None:
                raise ValueError("stochastic quantization requires rng")
            k = jax.random.fold_in(rng, zlib.crc32(name.encode()) & 0x7FFFFFFF)
            u = jax.random.uniform(k, leaf.shape, leaf.dtype)
            return finish(Q.quantize(leaf, spec.mode, alpha, u, stochastic=True),
                          alpha)
        if spec.mode in ("binary", "ternary"):
            return finish(Q.quantize(leaf, spec.mode, alpha, stochastic=False),
                          alpha)
        return cast(Q.apply_quant(leaf, spec, alpha, None))

    return jax.tree_util.tree_map_with_path(f, params, is_leaf=is_qtensor)


def clip_tree(params: Any, spec: Q.QuantSpec) -> Any:
    """Clip quantizable master leaves to [-alpha, alpha] after an optimizer
    step (keeps the Bernoulli probabilities valid)."""
    if not spec.enabled or spec.mode not in ("binary", "ternary"):
        return params
    policy = spec.policy()

    def f(path, leaf):
        if not is_qtensor(leaf) and policy.matches(path, leaf):
            return Q.clip_master(leaf, leaf_alpha(leaf.shape))
        return leaf

    return jax.tree_util.tree_map_with_path(f, params, is_leaf=is_qtensor)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def winit(key, shape, dtype=jnp.float32) -> Array:
    """Glorot-uniform init at the scale the quantizer expects."""
    a = leaf_alpha(shape)
    return jax.random.uniform(key, shape, dtype, -a, a)


def maybe_scale(params: dict, wname: str, spec: Q.QuantSpec, d_out: int, dtype) -> None:
    """Attach the per-output-channel scale companion for norm='channel'."""
    if spec.enabled and spec.norm == "channel":
        params["s" + wname[1:]] = jnp.ones((d_out,), dtype)


def scaled(y: Array, params: dict, wname: str, spec: Q.QuantSpec) -> Array:
    """Apply the channel-scale companion if configured."""
    s = params.get("s" + wname[1:])
    if spec.enabled and spec.norm == "channel" and s is not None:
        return y * s.astype(y.dtype)
    return y
