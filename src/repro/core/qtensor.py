"""`QTensor`: the one quantized-weight representation, training -> serving.

The paper's product is the HANDOFF — binary/ternary weights learned with
stochastic STE training become packed, MAC-free weights at inference (12x
memory / 10x claimed speedup).  `QTensor` is that artifact as a first-class
jax pytree:

  * `codes`   — uint32 bit-packed values, packed along the contraction axis
                ({0b00:0, 0b01:+1, 0b11:-1} 2-bit ternary, {0:-1, 1:+1}
                1-bit binary; see core/quantize.py).  Leading axes (layer
                stacks, experts) are preserved, so a stacked (R, K, N)
                master packs to (R, ceil(K/G), N) and `lax.scan` /
                `tree.map(lambda l: l[r], ...)` slice it exactly like the
                fp tree they replace.
  * `scale`   — optional per-output-channel fp companion (norm='channel').
  * `k`/`mode`/`alpha` — static metadata (true contraction length, 'binary'
                or 'ternary', the fixed Glorot alpha).  Static so a sliced
                or scanned QTensor keeps its semantics without carrying
                scalar arrays through tree transforms.

K that is not a multiple of the pack group is zero-padded at pack time; the
matmul wrapper zero-pads activations to the same boundary, so pad lanes
contribute exactly 0 regardless of their code values.

`export_packed(params, spec)` deterministically quantizes a trained master
tree into QTensors per the spec's `QuantPolicy` — the single export path for
the BN-LSTM, the transformer pool, and the serving kernels.  Consumption is
`repro.kernels.ops.qmatmul`, which dispatches QTensor operands to the Pallas
packed kernel and fp operands to `jnp.dot`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import quantize as Q

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QTensor:
    """Packed binary/ternary weight (see module docstring)."""

    codes: Array                                   # uint32 (..., ceil(K/G), N)
    scale: Optional[Array] = dataclasses.field(default=None)
    k: int = dataclasses.field(default=0, metadata=dict(static=True))
    mode: str = dataclasses.field(default="ternary", metadata=dict(static=True))
    alpha: float = dataclasses.field(default=1.0, metadata=dict(static=True))

    # -- metadata ----------------------------------------------------------

    @property
    def group(self) -> int:
        return Q.TERNARY_GROUP if self.mode == "ternary" else Q.BINARY_GROUP

    @property
    def shape(self) -> tuple:
        """Logical (unpacked) weight shape."""
        return self.codes.shape[:-2] + (self.k, self.codes.shape[-1])

    @property
    def ndim(self) -> int:
        return self.codes.ndim

    @property
    def nbytes(self) -> int:
        """Bytes actually stored/streamed for this weight."""
        n = self.codes.size * self.codes.dtype.itemsize
        if self.scale is not None:
            n += self.scale.size * self.scale.dtype.itemsize
        return n

    # -- construction ------------------------------------------------------

    @classmethod
    def from_master(cls, w: Array, mode: str, alpha: Optional[float] = None,
                    scale: Optional[Array] = None) -> "QTensor":
        """Deterministically quantize + pack a trained fp master weight.

        Deterministic (MAP) quantization is the paper's inference variant —
        Fig. 1b shows the stochastic/deterministic gap is negligible.
        w: (..., K, N); leading axes are layer-stack / expert dims.
        """
        if w.ndim < 2:
            raise ValueError(f"QTensor needs a matmul weight, got shape {w.shape}")
        if mode not in ("binary", "ternary"):
            raise ValueError(f"mode must be 'binary'|'ternary', got {mode!r}")
        alpha = float(alpha) if alpha is not None else Q.leaf_alpha(w.shape)
        group = Q.TERNARY_GROUP if mode == "ternary" else Q.BINARY_GROUP
        *lead, K, N = w.shape
        wn = jnp.clip(w.astype(jnp.float32) / alpha, -1.0, 1.0)
        qv = jnp.round(wn) if mode == "ternary" else jnp.where(wn >= 0, 1.0, -1.0)
        pad = (-K) % group
        if pad:
            qv = jnp.pad(qv, [(0, 0)] * len(lead) + [(0, pad), (0, 0)])
        pack = Q.pack_ternary if mode == "ternary" else Q.pack_binary
        flat = qv.reshape((-1, K + pad, N))
        codes = jax.vmap(pack)(flat).reshape(tuple(lead) + ((K + pad) // group, N))
        return cls(codes=codes, scale=scale, k=K, mode=mode, alpha=alpha)

    # -- dequantization (reference / gather paths) -------------------------

    def dequantize(self, dtype=jnp.float32) -> Array:
        """Materialize the effective fp weight alpha * values (* scale)."""
        unpack = Q.unpack_ternary if self.mode == "ternary" else Q.unpack_binary
        *lead, kg, N = self.codes.shape
        flat = self.codes.reshape((-1, kg, N))
        vals = jax.vmap(lambda c: unpack(c, kg * self.group, dtype))(flat)
        w = vals.reshape(tuple(lead) + (kg * self.group, N))[..., : self.k, :]
        w = (self.alpha * w).astype(dtype)
        if self.scale is not None:
            w = w * self.scale.astype(dtype)
        return w


def is_qtensor(x: Any) -> bool:
    return isinstance(x, QTensor)


def analytic_nbytes(shape, mode: str) -> int:
    """Serialized size a QTensor of logical `shape` will have (per-matrix
    packing: leading stack/expert axes each pad their own K groups)."""
    group = Q.TERNARY_GROUP if mode == "ternary" else Q.BINARY_GROUP
    *lead, K, N = shape
    n_mats = int(math.prod(lead)) if lead else 1
    return n_mats * math.ceil(K / group) * N * 4


# ---------------------------------------------------------------------------
# export: trained master tree -> packed serving tree
# ---------------------------------------------------------------------------


def export_packed(params: Any, spec: Q.QuantSpec, *,
                  policy: Optional[Q.QuantPolicy] = None) -> Any:
    """Deterministically quantize every policy-matching leaf into a QTensor.

    The returned tree has the same structure as `params` with quantizable
    matmul weights replaced by QTensors; everything else (embeddings, norms,
    biases, routers, BN/scale companions) passes through untouched.  Model
    code consumes either tree unmodified via `kernels.ops.qmatmul`.
    """
    if spec.mode not in ("binary", "ternary"):
        raise ValueError(
            f"export_packed needs a binary/ternary spec, got mode={spec.mode!r}")
    policy = policy if policy is not None else spec.policy()

    def f(path, leaf):
        if is_qtensor(leaf):
            return leaf  # already exported
        if not policy.matches(path, leaf):
            return leaf
        # embeddings are consumed by row gather, not matmul — keep them fp
        # even when the policy would quantize them (the gather is already
        # MAC-free; see DESIGN.md §3).
        if Q.path_str(path[-1:]) == "embed":
            return leaf
        return QTensor.from_master(leaf, spec.mode, Q.leaf_alpha(leaf.shape))

    return jax.tree_util.tree_map_with_path(f, params, is_leaf=is_qtensor)


def tree_nbytes(tree: Any) -> tuple[int, int]:
    """(fp32-equivalent bytes, actual bytes) over a (possibly packed) tree.

    The first element prices every logical parameter at 4 bytes — the
    fp32-master footprint the packed tree replaces; the second is what the
    tree actually stores (QTensor.nbytes for packed leaves)."""
    fp = real = 0
    for leaf in jax.tree_util.tree_leaves(tree, is_leaf=is_qtensor):
        if is_qtensor(leaf):
            fp += int(math.prod(leaf.shape)) * 4
            real += leaf.nbytes
        else:
            fp += leaf.size * 4
            real += leaf.size * leaf.dtype.itemsize if hasattr(leaf, "dtype") \
                else leaf.size * 4
    return fp, real
