"""Batch normalization for recurrent networks (paper Eq. 3).

  BN(x; phi, gamma) = gamma + phi * (x - E[x]) / sqrt(V[x] + eps)

Training uses current-minibatch statistics (per time step — the statistics are
recomputed at every step of the scan, matching the paper's "estimations ... for
the current minibatch").  Running averages are accumulated across steps and used
for inference, following Laurent et al. (2016); the paper does not prescribe
per-timestep inference statistics and its batch-size study (Fig. 3) uses shared
running statistics.

Functional style: `bn_apply(x, p, s, training)` returns `(y, new_state)` where
state carries running mean/var.  Under pjit the batch mean/var are *global*
(XLA turns the batch-axis reduction into a cross-replica reduction), so the
distributed semantics match single-device training exactly.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class BNParams(NamedTuple):
    phi: Array  # multiplicative (paper's phi)
    gamma: Array  # additive (paper's gamma; fixed 0 for gate pre-activations)


class BNState(NamedTuple):
    mean: Array
    var: Array
    count: Array  # number of updates folded into the running stats


def bn_init(features: int, *, phi_init: float = 0.1, gamma_init: float = 0.0,
            dtype=jnp.float32) -> tuple[BNParams, BNState]:
    """phi_init=0.1 follows recurrent-BN practice (Cooijmans et al. 2016):
    small phi keeps the sigmoid/tanh pre-activations in their linear regime."""
    p = BNParams(phi=jnp.full((features,), phi_init, dtype),
                 gamma=jnp.full((features,), gamma_init, dtype))
    s = BNState(mean=jnp.zeros((features,), dtype), var=jnp.ones((features,), dtype),
                count=jnp.zeros((), dtype))
    return p, s


def bn_apply(x: Array, p: BNParams, s: BNState, *, training: bool,
             trainable_gamma: bool = True, eps: float = 1e-5,
             momentum: float = 0.99) -> tuple[Array, BNState]:
    """x: (batch, features).  Returns normalized x and updated running stats."""
    if training:
        mean = jnp.mean(x, axis=0)
        var = jnp.var(x, axis=0)
        new_s = BNState(
            mean=momentum * s.mean + (1.0 - momentum) * jax.lax.stop_gradient(mean),
            var=momentum * s.var + (1.0 - momentum) * jax.lax.stop_gradient(var),
            count=s.count + 1.0,
        )
    else:
        mean, var = s.mean, s.var
        new_s = s
    gamma = p.gamma if trainable_gamma else jax.lax.stop_gradient(p.gamma)
    y = gamma + p.phi * (x - mean) * jax.lax.rsqrt(var + eps)
    return y, new_s
