"""Learning recurrent binary/ternary weights (Ardakani et al., ICLR 2019) — core.

Implements the paper's Eqs. (1), (4), (5), (6):

  * normalize master weights by a fixed Glorot-initialized scale alpha,
  * stochastically sample binary {-1,+1} / ternary {-1,0,+1} values from a
    Bernoulli whose probability is the (clipped) normalized weight,
  * straight-through estimator (STE) so gradients flow to the fp master weights,

plus the deterministic inference variants, the literature baselines the paper
compares against (BinaryConnect, TWN, TTQ, DoReFa k-bit), and bit-packing
(1-bit / 2-bit) used by the serving path and the Pallas kernels.

All functions are pure and jit/vmap/pjit friendly.  Stochasticity is driven by
an explicit uniform-noise operand (not a PRNG key inside the quantizer) so the
same code path is reusable inside Pallas kernels and trivially testable.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

# ---------------------------------------------------------------------------
# Scale alpha (paper: "alpha is a fixed scaling factor for all the weights and
# initialized from Glorot & Bengio (2010)").
# ---------------------------------------------------------------------------


def glorot_alpha(fan_in: int, fan_out: int) -> float:
    """Fixed per-matrix scale: the Glorot-uniform limit sqrt(6/(fan_in+fan_out))."""
    return math.sqrt(6.0 / float(fan_in + fan_out))


def leaf_alpha(shape) -> float:
    """Glorot alpha from the matmul dims (last two axes; leading axes are
    layer-stack / expert dims)."""
    if len(shape) < 2:
        return 1.0
    return glorot_alpha(int(shape[-2]), int(shape[-1]))


# ---------------------------------------------------------------------------
# Straight-through estimator (Eq. 1):  dL/dW  ≈  dL/dW^{B/T}
# Implemented as an identity-gradient wrapper around an arbitrary
# non-differentiable forward transform.
# ---------------------------------------------------------------------------


@jax.custom_vjp
def _ste(w: Array, q: Array) -> Array:
    """Returns q in the forward pass; gradient flows straight through to w."""
    del w
    return q


def _ste_fwd(w, q):
    del w
    return q, None


def _ste_bwd(_, g):
    # Gradient w.r.t. the master weights is the incoming gradient (Eq. 1);
    # the quantized branch gets no gradient (it is a sample, not a parameter).
    return g, jnp.zeros_like(g)


_ste.defvjp(_ste_fwd, _ste_bwd)


def ste(master: Array, quantized: Array) -> Array:
    """Straight-through: forward=quantized, backward=identity to master."""
    return _ste(master, jax.lax.stop_gradient(quantized))


# ---------------------------------------------------------------------------
# Stochastic binary / ternary quantization (Eqs. 4-6).
# ---------------------------------------------------------------------------


def _normalize(w: Array, alpha: Array | float) -> Array:
    """w^N = clip(w / alpha, -1, 1).  The clip realizes the Bernoulli-probability
    domain [0,1]; master weights are additionally clipped after each update
    (see `clip_master`), so this is a no-op at steady state."""
    return jnp.clip(w / alpha, -1.0, 1.0)


def binarize_stochastic(w: Array, u: Array, alpha: Array | float) -> Array:
    """Eq. (4)+(6): P(w=+1) = (w^N + 1)/2, sample, map to {-alpha, +alpha}.

    `u` is uniform(0,1) noise of w's shape.  Forward-only (no STE here).
    """
    wn = _normalize(w, alpha)
    p_one = (wn + 1.0) * 0.5
    b = jnp.where(u < p_one, 1.0, -1.0).astype(w.dtype)
    return alpha * b


def ternarize_stochastic(w: Array, u: Array, alpha: Array | float) -> Array:
    """Eq. (5)+(6): P(w=±1) = |w^N| (sign of w), P(w=0) = 1-|w^N|."""
    wn = _normalize(w, alpha)
    nonzero = (u < jnp.abs(wn)).astype(w.dtype)
    t = nonzero * jnp.sign(wn).astype(w.dtype)
    return alpha * t


def binarize_deterministic(w: Array, alpha: Array | float) -> Array:
    """Inference-time expectation argmax: sign(w^N) in {-1,+1} (sign(0):=+1)."""
    wn = _normalize(w, alpha)
    return alpha * jnp.where(wn >= 0, 1.0, -1.0).astype(w.dtype)


def ternarize_deterministic(w: Array, alpha: Array | float) -> Array:
    """Inference-time MAP value: round(w^N) in {-1,0,+1}."""
    wn = _normalize(w, alpha)
    return alpha * jnp.round(wn).astype(w.dtype)


def quantize(
    w: Array,
    mode: str,
    alpha: Array | float,
    u: Optional[Array] = None,
    *,
    stochastic: bool = True,
    with_ste: bool = True,
) -> Array:
    """The paper's quantizer as a single entry point.

    mode: 'binary' | 'ternary' | 'none' (passthrough)
    u:    uniform noise (required when stochastic=True and mode != 'none')
    """
    if mode == "none":
        return w
    if stochastic:
        if u is None:
            raise ValueError("stochastic quantization requires uniform noise u")
        q = (binarize_stochastic if mode == "binary" else ternarize_stochastic)(w, u, alpha)
    else:
        q = (binarize_deterministic if mode == "binary" else ternarize_deterministic)(w, alpha)
    return ste(w, q) if with_ste else q


def clip_master(w: Array, alpha: Array | float) -> Array:
    """Keep master weights inside [-alpha, alpha] after an optimizer step so the
    Bernoulli probabilities stay in [0,1] (BinaryConnect-style clipping, which
    the paper inherits)."""
    return jnp.clip(w, -alpha, alpha)


# ---------------------------------------------------------------------------
# Literature baselines the paper compares against (Tables 1-4).
# ---------------------------------------------------------------------------


def binaryconnect(w: Array) -> Array:
    """BinaryConnect (Courbariaux et al. 2015), deterministic: alpha*sign(w)
    with a single per-matrix scale alpha = E|w| and NO output normalization.
    This is the method the paper shows *fails* on LSTMs (Table 1: 4.24 BPC)."""
    alpha = jnp.mean(jnp.abs(w))
    q = alpha * jnp.where(w >= 0, 1.0, -1.0).astype(w.dtype)
    return ste(w, q)


def twn(w: Array) -> Array:
    """Ternary Weight Networks (Li & Liu 2016): threshold delta = 0.7*E|w|,
    alpha = E[|w| : |w|>delta] (L2-optimal scale for the ternary support)."""
    delta = 0.7 * jnp.mean(jnp.abs(w))
    mask = (jnp.abs(w) > delta).astype(w.dtype)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    alpha = jnp.sum(jnp.abs(w) * mask) / denom
    q = alpha * mask * jnp.sign(w)
    return ste(w, q)


def ttq(w: Array, alpha_pos: Array, alpha_neg: Array) -> Array:
    """Trained Ternary Quantization (Zhu et al. 2016): asymmetric *learned*
    scales for the positive / negative supports; threshold 0.05*max|w|."""
    delta = 0.05 * jnp.max(jnp.abs(w))
    pos = (w > delta).astype(w.dtype)
    neg = (w < -delta).astype(w.dtype)
    q = alpha_pos * pos - alpha_neg * neg
    # STE to master weights; alphas receive real gradients through q's scale.
    return ste(w, jax.lax.stop_gradient(q)) + (q - jax.lax.stop_gradient(q))


def dorefa(w: Array, bits: int) -> Array:
    """DoReFa-Net weight quantization to `bits` bits (Zhou et al. 2016)."""
    if bits == 1:
        return binaryconnect(w)
    t = jnp.tanh(w)
    wn = t / (2.0 * jnp.max(jnp.abs(t))) + 0.5  # [0,1]
    n = float(2**bits - 1)
    q = 2.0 * (jnp.round(wn * n) / n) - 1.0
    return ste(w, q * jnp.max(jnp.abs(w)))


# ---------------------------------------------------------------------------
# Bit packing.  Ternary: 2-bit codes {0b00: 0, 0b01: +1, 0b11: -1}, 16 / uint32.
# Binary: 1-bit codes {0: -1, 1: +1}, 32 / uint32.  Packing is along the
# *leading* (contraction) axis so a (K, N) weight packs to (K/16, N) — each
# lane of a VMEM tile unpacks independently (TPU-friendly: no cross-lane
# shuffles, just shift/and/select on the VPU).
# ---------------------------------------------------------------------------

TERNARY_GROUP = 16  # weights per uint32 (2 bits each)
BINARY_GROUP = 32  # weights per uint32 (1 bit each)


def pack_ternary(q: Array) -> Array:
    """Pack ternary values in {-1,0,+1} (any float/int dtype), shape (K, N)
    with K % 16 == 0, into uint32 of shape (K//16, N)."""
    k, n = q.shape
    if k % TERNARY_GROUP:
        raise ValueError(f"K={k} not a multiple of {TERNARY_GROUP}")
    codes = jnp.where(q > 0, 1, jnp.where(q < 0, 3, 0)).astype(jnp.uint32)
    codes = codes.reshape(k // TERNARY_GROUP, TERNARY_GROUP, n)
    shifts = (2 * jnp.arange(TERNARY_GROUP, dtype=jnp.uint32))[None, :, None]
    return jnp.sum(codes << shifts, axis=1, dtype=jnp.uint32)


def unpack_ternary(packed: Array, k: int, dtype=jnp.float32) -> Array:
    """Inverse of pack_ternary -> (k, N) array of {-1,0,+1}."""
    kg, n = packed.shape
    if kg * TERNARY_GROUP != k:
        raise ValueError(f"packed K {kg}*16 != {k}")
    shifts = (2 * jnp.arange(TERNARY_GROUP, dtype=jnp.uint32))[None, :, None]
    codes = (packed[:, None, :] >> shifts) & jnp.uint32(3)
    vals = jnp.where(codes == 1, 1.0, jnp.where(codes == 3, -1.0, 0.0)).astype(dtype)
    return vals.reshape(k, n)


def pack_binary(q: Array) -> Array:
    """Pack binary values in {-1,+1}, shape (K, N), K % 32 == 0 -> uint32 (K//32, N)."""
    k, n = q.shape
    if k % BINARY_GROUP:
        raise ValueError(f"K={k} not a multiple of {BINARY_GROUP}")
    bits = (q > 0).astype(jnp.uint32).reshape(k // BINARY_GROUP, BINARY_GROUP, n)
    shifts = jnp.arange(BINARY_GROUP, dtype=jnp.uint32)[None, :, None]
    return jnp.sum(bits << shifts, axis=1, dtype=jnp.uint32)


def unpack_binary(packed: Array, k: int, dtype=jnp.float32) -> Array:
    kg, n = packed.shape
    if kg * BINARY_GROUP != k:
        raise ValueError(f"packed K {kg}*32 != {k}")
    shifts = jnp.arange(BINARY_GROUP, dtype=jnp.uint32)[None, :, None]
    bits = (packed[:, None, :] >> shifts) & jnp.uint32(1)
    vals = (bits.astype(dtype) * 2.0 - 1.0).astype(dtype)
    return vals.reshape(k, n)


def packed_nbytes(shape: tuple[int, ...], mode: str) -> int:
    """Analytic serialized size of a packed weight (for the paper's size tables)."""
    k = int(np.prod(shape[:-1]))
    n = shape[-1]
    if mode == "binary":
        return math.ceil(k / BINARY_GROUP) * n * 4
    if mode == "ternary":
        return math.ceil(k / TERNARY_GROUP) * n * 4
    return k * n * 4  # fp32


# ---------------------------------------------------------------------------
# Quantization spec carried by configs, and the per-leaf policy resolved
# from it.
# ---------------------------------------------------------------------------


def path_str(path) -> str:
    """'/'-joined string form of a jax key-path.  The one canonical
    rendering — policy matching, the quantizer's per-leaf rng fold-in, and
    export all use it, so a leaf has exactly one name everywhere."""
    parts = []
    for p in path:
        for attr in ("key", "idx", "name"):
            if hasattr(p, attr):
                parts.append(str(getattr(p, attr)))
                break
        else:
            parts.append(str(p))
    return "/".join(parts)


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Explicit per-leaf quantization policy (DESIGN.md §3).

    Decides which parameter-tree leaves are *quantizable matmul weights* —
    the decision formerly hidden in a name-prefix convention.  Patterns are
    `fnmatch` globs evaluated against the leaf's own key and, when a pattern
    contains '/', against the full '/'-joined tree path.  Precedence:
    exclude > extra > include; leaves below `min_ndim` never quantize.
    """

    include: tuple = ()   # glob patterns of quantizable leaf names
    exclude: tuple = ()   # glob patterns force-kept full precision
    extra: tuple = ()     # exact leaf names additionally quantized
    min_ndim: int = 2     # vectors/scalars (biases, norms) never quantize

    def _hit(self, patterns, name: str, path_str: str) -> bool:
        from fnmatch import fnmatchcase
        for pat in patterns:
            target = path_str if "/" in pat else name
            if fnmatchcase(target, pat):
                return True
        return False

    def matches_name(self, name: str, path_str: Optional[str] = None,
                     ndim: Optional[int] = None) -> bool:
        path_str = path_str if path_str is not None else name
        if ndim is not None and ndim < self.min_ndim:
            return False
        if self._hit(self.exclude, name, path_str):
            return False
        if name in self.extra:
            return True
        return self._hit(self.include, name, path_str)

    def matches(self, path, leaf=None) -> bool:
        """path: a jax key-path (tuple of DictKey/GetAttrKey/SequenceKey)."""
        name = path_str(path[-1:]) if path else ""
        ndim = getattr(leaf, "ndim", None) if leaf is not None else None
        return self.matches_name(name, path_str(path), ndim)


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """How the paper's technique is applied to a model's matmuls."""

    mode: str = "none"  # none | binary | ternary | binaryconnect | twn | dorefa2..4
    stochastic: bool = True  # Bernoulli sampling (train); False -> deterministic
    norm: str = "batch"  # 'batch' (paper Eq.7, for RNNs) | 'channel' (transformer adaptation) | 'none'
    quantize_embeddings: bool = False  # paper keeps classifier/embedding fp
    # beyond-paper: route the FSDP/TP weight all-gathers through the 2-bit/
    # 1-bit PACKED representation (quantize+pack shard-local, gather uint32
    # codes, unpack on-chip).  16x/32x fewer wire bytes than fp32 masters —
    # the paper's memory-bandwidth claim applied to the interconnect.
    packed_comms: bool = False
    # per-leaf policy knobs: glob patterns over leaf names (see QuantPolicy).
    # The default mirrors the repo-wide convention (capital-W matmul weights
    # quantize; embeddings/norms/biases/routers/scale companions stay fp) but
    # is now explicit, overridable data rather than code.
    include: tuple = ("W*",)
    exclude: tuple = ()

    @property
    def enabled(self) -> bool:
        return self.mode != "none"

    @property
    def weight_bits(self) -> float:
        return {"binary": 1, "binaryconnect": 1, "ternary": 2, "twn": 2,
                "dorefa2": 2, "dorefa3": 3, "dorefa4": 4}.get(self.mode, 32)

    def policy(self) -> QuantPolicy:
        """Resolve the per-leaf policy this spec implies."""
        extra = ("embed", "head") if self.quantize_embeddings else ()
        return QuantPolicy(include=tuple(self.include),
                           exclude=tuple(self.exclude), extra=extra)


def apply_quant(w: Array, spec: QuantSpec, alpha: Array | float, u: Optional[Array]) -> Array:
    """Dispatch a weight matrix through the configured quantizer (training path)."""
    m = spec.mode
    if m == "none":
        return w
    if m in ("binary", "ternary"):
        return quantize(w, m, alpha, u, stochastic=spec.stochastic)
    if m == "binaryconnect":
        return binaryconnect(w)
    if m == "twn":
        return twn(w)
    if m.startswith("dorefa"):
        return dorefa(w, int(m[len("dorefa"):]))
    raise ValueError(f"unknown quant mode {m!r}")
