"""BN-LSTM / BN-GRU with learned recurrent binary/ternary weights.

Faithful implementation of the paper's Algorithm 1 / Eq. (7):

  * master weights W_{*h}, W_{*x} are fp32; they are quantized ONCE per forward
    pass (before the time loop, Algorithm 1 lines 3-6),
  * every vector-matrix product is batch-normalized with a learned scale phi
    and additive term fixed to 0 (Eq. 7),
  * the cell state is optionally batch-normalized with learned (phi_c, gamma_c)
    (Algorithm 1 line 13),
  * biases, BN parameters and the softmax classifier stay full-precision.

The four gates (f, i, o, g) are fused into single (d, 4H) matmuls; BN is
per-column so the fused form is mathematically identical to eight separate
BN(W·) terms.  The time loop is a `jax.lax.scan`, so the HLO stays small and
the same code path scales from the CPU tests to the pod-level dry run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import quantize as Q
from repro.core.qtensor import export_packed, is_qtensor
from repro.core.recurrent_bn import BNParams, BNState, bn_apply, bn_init
from repro.kernels import dispatch
from repro.kernels import ops as OPS

Array = jax.Array

# The BN-LSTM keeps the paper's lowercase parameter names; this is the
# explicit QuantPolicy equivalent of Algorithm 1's split (quantize the
# recurrent/input matrices, keep the softmax classifier 'ws' and all
# biases/BN parameters fp).
RNN_POLICY = Q.QuantPolicy(include=("wx", "wh"))


@dataclasses.dataclass(frozen=True)
class RNNConfig:
    vocab: int
    d_hidden: int
    n_layers: int = 1
    cell: str = "lstm"  # 'lstm' | 'gru'
    quant: Q.QuantSpec = Q.QuantSpec(mode="ternary", norm="batch")
    cell_norm: bool = True  # BN on the cell state (Algorithm 1 line 13)
    eps: float = 1e-5
    momentum: float = 0.99
    dtype: Any = jnp.float32

    @property
    def n_gates(self) -> int:
        return 4 if self.cell == "lstm" else 3


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _layer_init(key, d_in: int, cfg: RNNConfig) -> dict:
    h, g = cfg.d_hidden, cfg.n_gates
    kx, kh = jax.random.split(key)
    ax = Q.glorot_alpha(d_in, g * h)
    ah = Q.glorot_alpha(h, g * h)
    wx = jax.random.uniform(kx, (d_in, g * h), cfg.dtype, -ax, ax)
    wh = jax.random.uniform(kh, (h, g * h), cfg.dtype, -ah, ah)
    bn_x, bn_x_s = bn_init(g * h, dtype=cfg.dtype)
    bn_h, bn_h_s = bn_init(g * h, dtype=cfg.dtype)
    bn_c, bn_c_s = bn_init(h, dtype=cfg.dtype)
    params = {
        "wx": wx, "wh": wh, "b": jnp.zeros((g * h,), cfg.dtype),
        "bn_x": bn_x, "bn_h": bn_h, "bn_c": bn_c,
    }
    state = {"bn_x": bn_x_s, "bn_h": bn_h_s, "bn_c": bn_c_s}
    return {"params": params, "state": state}


def rnn_lm_init(key, cfg: RNNConfig) -> dict:
    """Returns {'params': trainable, 'state': BN running stats}."""
    keys = jax.random.split(key, cfg.n_layers + 1)
    layers = []
    d_in = cfg.vocab
    for l in range(cfg.n_layers):
        layers.append(_layer_init(keys[l], d_in, cfg))
        d_in = cfg.d_hidden
    ks = keys[-1]
    a = Q.glorot_alpha(cfg.d_hidden, cfg.vocab)
    head = {"ws": jax.random.uniform(ks, (cfg.d_hidden, cfg.vocab), cfg.dtype, -a, a),
            "bs": jnp.zeros((cfg.vocab,), cfg.dtype)}
    return {
        "params": {"layers": [l["params"] for l in layers], "head": head},
        "state": {"layers": [l["state"] for l in layers]},
    }


# ---------------------------------------------------------------------------
# quantize weights once per forward pass (Algorithm 1 lines 2-6)
# ---------------------------------------------------------------------------


def export_packed_rnn(params: dict, cfg: RNNConfig) -> dict:
    """Pack a trained BN-LSTM/GRU master tree for serving: every `wx`/`wh`
    becomes a QTensor; head + biases + BN parameters stay fp.  The result
    feeds `rnn_lm_apply` unchanged (training=False)."""
    return export_packed(params, cfg.quant, policy=RNN_POLICY)


def serving_variables(params: dict, bn_state: dict, cfg: RNNConfig) -> dict:
    """The train->serve handoff in one call (DESIGN.md §13): pack the
    trained fp masters and carry the training run's BN running statistics
    along as the FROZEN eval-time statistics.

    Serving always runs training=False, so `bn_apply` normalizes with these
    running (mean, var) — the per-timestep minibatch statistics of training
    never exist at decode time (batch of 1, step by step).  Handing the
    state over untouched is what makes the serving model the same function
    the validation BPC measured; `rnn_decode_tables` later folds these
    statistics into per-gate affines once per export."""
    return {"params": export_packed_rnn(params, cfg), "state": bn_state}


def _quantized_weights(params, cfg: RNNConfig, rng: Optional[Array],
                       training: bool = True):
    out = []
    stochastic = (cfg.quant.stochastic and training
                  and cfg.quant.mode in ("binary", "ternary"))
    for l, lp in enumerate(params["layers"]):
        wx, wh = lp["wx"], lp["wh"]
        if is_qtensor(wx) and is_qtensor(wh):
            # exported packed tree: weights are already the serving artifact
            out.append((wx, wh))
            continue
        if is_qtensor(wx) or is_qtensor(wh):
            raise ValueError(
                f"layer {l}: mixed packed/fp weights (wx packed={is_qtensor(wx)}, "
                f"wh packed={is_qtensor(wh)}); export both or neither — a raw "
                f"master here would silently serve unquantized")
        ax = Q.glorot_alpha(*wx.shape)
        ah = Q.glorot_alpha(*wh.shape)
        if cfg.quant.enabled and stochastic:
            if rng is None:
                raise ValueError("stochastic quantization needs an rng key in training mode")
            kx, kh = jax.random.split(jax.random.fold_in(rng, l))
            ux = jax.random.uniform(kx, wx.shape, wx.dtype)
            uh = jax.random.uniform(kh, wh.shape, wh.dtype)
        else:
            ux = uh = None
        if cfg.quant.mode in ("binary", "ternary") and not stochastic:
            # inference: deterministic expectation (paper Fig. 1b shows the
            # stochastic/deterministic gap is negligible)
            qx = Q.quantize(wx, cfg.quant.mode, ax, stochastic=False)
            qh = Q.quantize(wh, cfg.quant.mode, ah, stochastic=False)
        else:
            qx = Q.apply_quant(wx, cfg.quant, ax, ux)
            qh = Q.apply_quant(wh, cfg.quant, ah, uh)
        out.append((qx, qh))
    return out


# ---------------------------------------------------------------------------
# cells.  x_t arrives as int tokens for layer 0 (gather == one-hot matmul).
# ---------------------------------------------------------------------------


def _lstm_step(h, c, ax, ah, b, bn_c_p, bn_c_s, cfg: RNNConfig, training):
    pre = ax + ah + b
    f, i, o, g = jnp.split(pre, 4, axis=-1)
    c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    if cfg.cell_norm:
        cn, bn_c_s = bn_apply(c, bn_c_p, bn_c_s, training=training,
                              eps=cfg.eps, momentum=cfg.momentum)
    else:
        cn = c
    h = jax.nn.sigmoid(o) * jnp.tanh(cn)
    return h, c, bn_c_s


def _gru_step(h, ax_rz, ah_rz, ax_g, ah_g, b, training):
    # ax_*, ah_* are already batch-normalized slices; b = (3H,)
    b_r, b_z, b_g = jnp.split(b, 3, axis=-1)
    r = jax.nn.sigmoid(ax_rz[0] + ah_rz[0] + b_r)
    z = jax.nn.sigmoid(ax_rz[1] + ah_rz[1] + b_z)
    g = jnp.tanh(ax_g + r * ah_g + b_g)
    return (1.0 - z) * h + z * g


def rnn_lm_apply(variables: dict, tokens: Array, cfg: RNNConfig, *,
                 training: bool, rng: Optional[Array] = None,
                 return_state: bool = False, features_only: bool = False):
    """tokens: (B, T) int32.  Returns logits (B, T, vocab) and, when
    `return_state`, the updated BN running stats.  `features_only` skips the
    softmax head and returns the top layer's hidden states (B, T, H) —
    classification tasks (sequential MNIST, QA readouts) attach their own
    heads there."""
    params, state = variables["params"], variables["state"]
    B, T = tokens.shape
    qw = _quantized_weights(params, cfg, rng, training=training)

    x_seq = tokens  # layer 0 consumes token ids (gather == one-hot @ Wx)
    new_state = {"layers": []}
    for l in range(cfg.n_layers):
        lp, ls = params["layers"][l], state["layers"][l]
        qx, qh = qw[l]
        h0 = jnp.zeros((B, cfg.d_hidden), cfg.dtype)
        c0 = jnp.zeros((B, cfg.d_hidden), cfg.dtype)

        if l == 0:
            # (B,T) gather of quantized rows — identical to one-hot @ qx.
            # A packed qx decodes first: the gather itself is already
            # MAC-free, and layer 0's input projection is the one place the
            # serving path touches whole rows instead of a matmul.
            rows = qx.dequantize(cfg.dtype) if is_qtensor(qx) else qx
            x_proj_seq = jnp.take(rows, x_seq, axis=0)  # (B, T, gH)
        else:
            x_proj_seq = OPS.qmatmul(x_seq, qx)

        if cfg.cell == "lstm":
            def step(carry, x_proj_t):
                h, c, s_x, s_h, s_c = carry
                axn, s_x = bn_apply(x_proj_t, lp["bn_x"], s_x, training=training,
                                    trainable_gamma=False, eps=cfg.eps, momentum=cfg.momentum)
                ahn, s_h = bn_apply(OPS.qmatmul(h, qh), lp["bn_h"], s_h,
                                    training=training, trainable_gamma=False,
                                    eps=cfg.eps, momentum=cfg.momentum)
                h, c, s_c = _lstm_step(h, c, axn, ahn, lp["b"], lp["bn_c"], s_c, cfg, training)
                return (h, c, s_x, s_h, s_c), h

            carry0 = (h0, c0, ls["bn_x"], ls["bn_h"], ls["bn_c"])
            (hT, cT, s_x, s_h, s_c), hs = jax.lax.scan(
                step, carry0, jnp.swapaxes(x_proj_seq, 0, 1))
        else:  # gru
            def step(carry, x_proj_t):
                h, s_x, s_h = carry
                axn, s_x = bn_apply(x_proj_t, lp["bn_x"], s_x, training=training,
                                    trainable_gamma=False, eps=cfg.eps, momentum=cfg.momentum)
                ahn, s_h = bn_apply(OPS.qmatmul(h, qh), lp["bn_h"], s_h,
                                    training=training, trainable_gamma=False,
                                    eps=cfg.eps, momentum=cfg.momentum)
                H = cfg.d_hidden
                ax_r, ax_z, ax_g = axn[..., :H], axn[..., H:2 * H], axn[..., 2 * H:]
                ah_r, ah_z, ah_g = ahn[..., :H], ahn[..., H:2 * H], ahn[..., 2 * H:]
                h = _gru_step(h, (ax_r, ax_z), (ah_r, ah_z), ax_g, ah_g, lp["b"], training)
                return (h, s_x, s_h), h

            carry0 = (h0, ls["bn_x"], ls["bn_h"])
            (hT, s_x, s_h), hs = jax.lax.scan(step, carry0, jnp.swapaxes(x_proj_seq, 0, 1))
            s_c = ls["bn_c"]

        x_seq = jnp.swapaxes(hs, 0, 1)  # (B, T, H)
        new_state["layers"].append({"bn_x": s_x, "bn_h": s_h, "bn_c": s_c})

    if features_only:
        out = x_seq
    else:
        out = jnp.einsum("bth,hv->btv", x_seq, params["head"]["ws"]) \
            + params["head"]["bs"]
    if return_state:
        return out, new_state
    return out


# ---------------------------------------------------------------------------
# stateful serving: prefill / decode_step against frozen BN statistics
# (DESIGN.md §6).  At inference every BN is a per-column affine
#   y = x * (phi * rsqrt(var + eps)) + (gamma - phi * mean * rsqrt(var + eps))
# so the whole serving forward is gathers, (packed) matmuls, affines and gate
# nonlinearities — exactly the shape the fused Pallas decode kernel consumes.
# ---------------------------------------------------------------------------


class RNNState(NamedTuple):
    """Per-session recurrent state: stacked per-layer hidden/cell vectors.

    `c` is carried but unused for GRU cells (kept zeros) so LSTM and GRU share
    one state layout and the serving runtime never branches on cell type."""

    h: Array    # (n_layers, B, H)
    c: Array    # (n_layers, B, H)
    pos: Array  # () int32 — tokens consumed; (B,) in a per-slot pool


def rnn_state_init(cfg: RNNConfig, batch: int, dtype=None, *,
                   per_slot: bool = False) -> RNNState:
    """`per_slot` gives each batch row its own token counter (B,) — the
    continuous-batching pool layout, where slots sit at different depths.
    `pos` is bookkeeping (the recurrence itself is position-free), so both
    layouts run the identical prefill/decode compute."""
    dtype = dtype or cfg.dtype
    z = jnp.zeros((cfg.n_layers, batch, cfg.d_hidden), dtype)
    pos = jnp.zeros((batch,) if per_slot else (), jnp.int32)
    return RNNState(h=z, c=z, pos=pos)


def rnn_write_slots(state: RNNState, sub: RNNState, slots) -> RNNState:
    """Insert a k-sequence state into rows `slots` of a per-slot pool.

    The O(1) recurrent state is the whole trick: admission is two (L, H)
    row copies per slot, no KV bytes move.  `slots`: scalar or (k,) int;
    `sub`: batch-k state (its pos may be scalar — a freshly prefilled
    single request — or (k,))."""
    slots = jnp.atleast_1d(jnp.asarray(slots, jnp.int32))
    sub_pos = jnp.broadcast_to(jnp.asarray(sub.pos), slots.shape)
    return RNNState(h=state.h.at[:, slots].set(sub.h),
                    c=state.c.at[:, slots].set(sub.c),
                    pos=state.pos.at[slots].set(sub_pos))


def _bn_affine(p: BNParams, s: BNState, eps: float) -> tuple[Array, Array]:
    """Frozen inference BN as (scale, shift): y = x * scale + shift."""
    inv = jax.lax.rsqrt(s.var + eps)
    return p.phi * inv, p.gamma - p.phi * s.mean * inv


def rnn_decode_tables(variables: dict, cfg: RNNConfig, *,
                      dense: Optional[bool] = None) -> list:
    """Per-session serving artifacts, computed ONCE and reused every step.

    Per layer: deterministic/packed weights, the h-side and x-side BN affines,
    the cell-norm affine, and — for layer 0 — the token gather table with the
    x-side BN already folded in (`rows_bn`), so serving never dequantizes the
    embedding rows per call.  When the whole tree serves packed, the tables
    additionally carry the stacked whole-tick artifact (`tables[0]["tick"]`,
    see `_tick_tables`) that `rnn_decode_step` feeds the single-launch fused
    Pallas decode kernel.

    `dense` expands packed weights into DENSE fp tables at session setup,
    the same once-per-session dequantize layer 0's `rows_bn` already gets:
    the serving tree stays the packed QTensor export (memory is still the
    2-bit codes), but every step runs plain dense matmuls.  `dense=None`
    asks `kernels/dispatch.py` for the backend-honest answer — True on CPU
    (where packed Pallas kernels would only run emulated), False on real
    accelerators.  Parity tests opt into the packed tables on CPU with an
    explicit `dense=False`."""
    dense = dispatch.prefer_dense(dense)
    params, bn_state = variables["params"], variables["state"]
    qw = _quantized_weights(params, cfg, None, training=False)
    tables = []
    for l in range(cfg.n_layers):
        lp, ls = params["layers"][l], bn_state["layers"][l]
        qx, qh = qw[l]
        if dense and is_qtensor(qh):
            qh = qh.dequantize(cfg.dtype)
        if dense and is_qtensor(qx):
            qx = qx.dequantize(cfg.dtype)
        sx, tx = _bn_affine(lp["bn_x"], ls["bn_x"], cfg.eps)
        sh, th = _bn_affine(lp["bn_h"], ls["bn_h"], cfg.eps)
        if cfg.cell == "lstm" and cfg.cell_norm:
            sc, tc = _bn_affine(lp["bn_c"], ls["bn_c"], cfg.eps)
        else:
            sc = jnp.ones((cfg.d_hidden,), cfg.dtype)
            tc = jnp.zeros((cfg.d_hidden,), cfg.dtype)
        t = {"qh": qh, "b": lp["b"], "scale_h": sh, "shift_h": th,
             "scale_c": sc, "shift_c": tc}
        if l == 0:
            rows = qx.dequantize(cfg.dtype) if is_qtensor(qx) else qx
            t["rows_bn"] = rows * sx + tx  # gather -> already-BN'd preact
        else:
            t["qx"] = qx
            t["scale_x"], t["shift_x"] = sx, tx
        tables.append(t)
    packed = (all(is_qtensor(t["qh"]) and t["qh"].scale is None
                  for t in tables)
              and all(is_qtensor(t["qx"]) and t["qx"].scale is None
                      for t in tables[1:]))
    if packed:
        tables[0]["tick"] = _tick_tables(params, tables, cfg)
    return tables


def _tick_tables(params: dict, tables: list, cfg: RNNConfig) -> dict:
    """Stacked, padded, fold-complete operands for the whole-tick fused
    kernel (`ops.fused_decode_tick`) — built once per serving session.

    Everything a tick needs beyond the token ids and the carried h/c,
    pre-stacked over layers so the kernel scans them with a static index:
    gate-aligned packed codes for the h-side (all layers) and x-side
    (layers >= 1), the frozen-BN affines with the QTensor alpha folded into
    the scales and the bias folded into the input-side shifts (layer 0's
    bias folds into the `rows0` gather table), the cell-norm affine, and
    the padded fp head with finfo.min bias pads so pad logit columns can
    never win the in-kernel argmax.  ARRAYS ONLY: the dict rides through
    the engine's jits as part of the tables pytree argument."""
    from repro.kernels.decode_step import BN_TILE

    g, H = cfg.n_gates, cfg.d_hidden
    hp = -(-H // BN_TILE) * BN_TILE
    f32 = jnp.float32
    pad_g = lambda a: jnp.pad(a.astype(f32).reshape(g, H),
                              ((0, 0), (0, hp - H)))
    pad_1 = lambda a: jnp.pad(a.astype(f32).reshape(1, H),
                              ((0, 0), (0, hp - H)))
    codes_h, sh, th, sc, tc = [], [], [], [], []
    codes_x, sx, tx = [], [], []
    rows0 = None
    for l, t in enumerate(tables):
        codes_h.append(OPS.prepare_gate_codes(t["qh"], g))
        sh.append(pad_g(t["scale_h"] * t["qh"].alpha))
        th.append(pad_g(t["shift_h"]))
        sc.append(pad_1(t["scale_c"]))
        tc.append(pad_1(t["shift_c"]))
        if l == 0:
            rows0 = (t["rows_bn"] + t["b"]).astype(f32)
        else:
            codes_x.append(OPS.prepare_gate_codes(t["qx"], g))
            sx.append(pad_g(t["scale_x"] * t["qx"].alpha))
            tx.append(pad_g(t["shift_x"] + t["b"]))
    if not codes_x:  # single layer: dummy operand the kernel never reads
        codes_x = [jnp.zeros_like(codes_h[0])]
        sx = [jnp.zeros((g, hp), f32)]
        tx = [jnp.zeros((g, hp), f32)]
    head = params["head"]
    V = cfg.vocab
    vp = -(-V // BN_TILE) * BN_TILE
    ws = jnp.pad(head["ws"].astype(f32), ((0, hp - H), (0, vp - V)))
    bs = jnp.full((1, vp), jnp.finfo(f32).min, f32)
    bs = bs.at[0, :V].set(head["bs"].astype(f32))
    return {"rows0": rows0, "codes_h": jnp.stack(codes_h),
            "codes_x": jnp.stack(codes_x), "scale_h": jnp.stack(sh),
            "shift_h": jnp.stack(th), "scale_x": jnp.stack(sx),
            "shift_x": jnp.stack(tx), "scale_c": jnp.stack(sc),
            "shift_c": jnp.stack(tc), "ws": ws, "bs": bs}


def _serve_lstm_step(t: dict, ax: Array, h: Array, c: Array):
    """ax: (B, 4H) BN'd input-side preact (no bias).  Returns (h', c')."""
    ah = OPS.qmatmul(h, t["qh"]) * t["scale_h"] + t["shift_h"]
    f, i, o, g = jnp.split(ax + ah + t["b"], 4, axis=-1)
    c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    cn = c * t["scale_c"] + t["shift_c"]
    h = jax.nn.sigmoid(o) * jnp.tanh(cn)
    return h, c


def _serve_gru_step(t: dict, ax: Array, h: Array):
    """ax: (B, 3H) BN'd input-side preact (no bias).  Returns h'."""
    ah = OPS.qmatmul(h, t["qh"]) * t["scale_h"] + t["shift_h"]
    axb = ax + t["b"]
    H = h.shape[-1]
    r = jax.nn.sigmoid(axb[..., :H] + ah[..., :H])
    z = jax.nn.sigmoid(axb[..., H:2 * H] + ah[..., H:2 * H])
    g = jnp.tanh(axb[..., 2 * H:] + r * ah[..., 2 * H:])
    return (1.0 - z) * h + z * g


def _serve_x_preact(t: dict, l: int, x, dtype):
    """Input-side BN'd pre-activation: layer 0 gathers the folded row table
    (token ids in, no matmul); deeper layers project the layer below."""
    if l == 0:
        return jnp.take(t["rows_bn"], x, axis=0).astype(dtype)
    return OPS.qmatmul(x, t["qx"]) * t["scale_x"] + t["shift_x"]


def _serve_scan_layer(t: dict, ax_seq: Array, h0: Array, c0: Array,
                      cell: str):
    """One layer's serving scan — THE shared body of `rnn_prefill` and
    `rnn_prefill_chunk`.  Both must compile this exact step with these
    exact emitted outputs: XLA fuses (and therefore rounds) a scan body
    differently if its outputs differ, and whole-vs-chunked prefill being
    bit-identical depends on the shared body.  Returns (hs, cs, hl, cl):
    the per-step h/c stacked over time (cs = None for GRU) and the final
    carry."""
    if cell == "lstm":
        def step(carry, ax_t):
            h, c = _serve_lstm_step(t, ax_t, *carry)
            return (h, c), (h, c)
        (hl, cl), (hs, cs) = jax.lax.scan(step, (h0, c0),
                                          jnp.swapaxes(ax_seq, 0, 1))
        return hs, cs, hl, cl

    def step(h, ax_t):
        h = _serve_gru_step(t, ax_t, h)
        return h, h
    hl, hs = jax.lax.scan(step, h0, jnp.swapaxes(ax_seq, 0, 1))
    return hs, None, hl, c0


def rnn_logits_last(variables: dict, state: RNNState, cfg: RNNConfig) -> Array:
    """Next-token logits (B, vocab) from a carried state's top-layer h.

    Both prefill flavours (full `rnn_prefill` and the engine's bucket-padded
    `rnn_prefill_chunk`) sample the request's first token through THIS
    helper, at the same (B, 1, H) matmul shape — matmul rounding depends on
    the row count, so sharing the shape is what makes the chunked engine's
    first token bit-identical to the sequential loop's."""
    head = variables["params"]["head"]
    x = state.h[-1].astype(cfg.dtype)[:, None]  # (B, 1, H)
    return (OPS.qmatmul(x, head["ws"]) + head["bs"])[:, 0]


def rnn_prefill(variables: dict, tokens: Array, cfg: RNNConfig,
                state: Optional[RNNState] = None, *,
                tables: Optional[list] = None):
    """Run the prompt through the RNN, carrying state across calls.

    tokens: (B, T) int32.  Returns (logits (B, T, vocab), new RNNState) —
    full-sequence logits so callers can score the prompt; the serving loop
    samples from `rnn_logits_last` on the returned state.

    Runs `_serve_scan_layer` per layer — the body shared with
    `rnn_prefill_chunk` — so the carried state after T tokens is
    bit-identical whether the prompt ran whole or in chunks."""
    params = variables["params"]
    B, T = tokens.shape
    if state is None:
        state = rnn_state_init(cfg, B)
    if tables is None:
        tables = rnn_decode_tables(variables, cfg)

    x_seq = tokens
    hT, cT = [], []
    for l, t in enumerate(tables):
        ax_seq = _serve_x_preact(t, l, x_seq, cfg.dtype)  # (B, T, gH)
        hs, _, hl, cl = _serve_scan_layer(
            t, ax_seq, state.h[l].astype(cfg.dtype),
            state.c[l].astype(cfg.dtype), cfg.cell)
        x_seq = jnp.swapaxes(hs, 0, 1)
        hT.append(hl)
        cT.append(cl)

    logits = OPS.qmatmul(x_seq, params["head"]["ws"]) + params["head"]["bs"]
    new_state = RNNState(h=jnp.stack(hT), c=jnp.stack(cT),
                         pos=state.pos + jnp.int32(T))
    return logits, new_state


def rnn_prefill_chunk(variables: dict, tokens: Array, cfg: RNNConfig,
                      state: RNNState, *, n: Array,
                      tables: Optional[list] = None):
    """One bucket-padded prompt chunk: consume the first `n` of T tokens.

    tokens: (B, T) int32 where T is a BUCKET length (static — one jit trace
    per bucket) and `n` (traced int32) is the real token count, 1 <= n <= T.
    The scan body is `_serve_scan_layer` — EXACTLY `rnn_prefill`'s, so XLA
    fuses and rounds identically; the pad tokens simply run past the end
    and the state at token n-1 is picked out of the per-step outputs.
    Pad steps feed on real outputs but their own outputs are discarded, so
    the returned state and logits are bit-identical to running the unpadded
    slice through `rnn_prefill`, with a trace count that depends on the
    bucket set, not on prompt lengths.  The continuous-batching engine
    resumes a prompt across chunks with this; the carried `state` makes it
    O(1) per chunk regardless of how much prompt came before."""
    B, T = tokens.shape
    if tables is None:
        tables = rnn_decode_tables(variables, cfg)
    n = jnp.asarray(n, jnp.int32)

    x_seq = tokens
    hT, cT = [], []
    for l, t in enumerate(tables):
        ax_seq = _serve_x_preact(t, l, x_seq, cfg.dtype)  # (B, T, gH)
        hs, cs, _, carry_c = _serve_scan_layer(
            t, ax_seq, state.h[l].astype(cfg.dtype),
            state.c[l].astype(cfg.dtype), cfg.cell)
        hl = jnp.take(hs, n - 1, axis=0)
        # GRU carries no cell: _serve_scan_layer returns the c0 it was given
        cl = jnp.take(cs, n - 1, axis=0) if cs is not None else carry_c
        x_seq = jnp.swapaxes(hs, 0, 1)
        hT.append(hl)
        cT.append(cl)

    new_state = RNNState(h=jnp.stack(hT), c=jnp.stack(cT), pos=state.pos + n)
    # first-token logits through the SAME (B, 1, H) head shape the
    # sequential loop samples from (rnn_logits_last) — bit-for-bit equal
    return rnn_logits_last(variables, new_state, cfg), new_state


def rnn_decode_step(variables: dict, tok: Array, cfg: RNNConfig,
                    state: RNNState, *, tables: Optional[list] = None,
                    fused: Optional[bool] = None,
                    live: Optional[Array] = None,
                    interpret: Optional[bool] = None):
    """One serving step.  tok: (B,) or (B, 1) int32.

    Returns (logits (B, vocab), new RNNState).  With packed tables the WHOLE
    tick — every layer's accumulation-only h-side GEMV + BN affine + bias +
    gate nonlinearities, plus the logits head when it fits VMEM — runs as
    ONE fused Pallas launch (kernels/decode_step.py); `fused=False` forces
    the unfused qmatmul path (the parity oracle), `fused=True` requires the
    packed whole-tick tables.

    `live` (B,) bool freezes dead continuous-batching slots: masked rows
    keep their h/c (and pos) bit-for-bit while live rows step normally, so
    the engine runs ONE batched step per tick at fixed shape regardless of
    occupancy.  The fused kernel applies the mask in-launch; the unfused
    path selects after the step.  Dead rows' logits are garbage — the
    engine never samples from them."""
    params = variables["params"]
    if tok.ndim == 2:
        tok = tok[:, 0]
    if tables is None:
        tables = rnn_decode_tables(variables, cfg)

    tick = tables[0].get("tick")
    use_tick = (tick is not None) if fused is None else fused
    if use_tick:
        if tick is None:
            raise ValueError("fused decode needs packed (QTensor) weights; "
                             "export the tree (dense=False tables) or pass "
                             "fused=False")
        logits, hT, cT, _greedy = OPS.fused_decode_tick(
            tok, state.h.astype(cfg.dtype), state.c.astype(cfg.dtype), tick,
            cell=cfg.cell, mode=tables[0]["qh"].mode, vocab=cfg.vocab,
            live=live, interpret=interpret)
        step = 1 if live is None else live.astype(state.pos.dtype)
        return logits, RNNState(h=hT, c=cT, pos=state.pos + step)

    x = tok
    hT, cT = [], []
    for l, t in enumerate(tables):
        ax = _serve_x_preact(t, l, x, cfg.dtype)
        h = state.h[l].astype(cfg.dtype)
        c = state.c[l].astype(cfg.dtype)
        if cfg.cell == "lstm":
            hn, cn = _serve_lstm_step(t, ax, h, c)
        else:
            hn, cn = _serve_gru_step(t, ax, h), c
        if live is not None:
            hn = jnp.where(live[:, None], hn, h)
            cn = jnp.where(live[:, None], cn, c)
        hT.append(hn)
        cT.append(cn)
        x = hn

    logits = OPS.qmatmul(x, params["head"]["ws"]) + params["head"]["bs"]
    step = 1 if live is None else live.astype(state.pos.dtype)
    new_state = RNNState(h=jnp.stack(hT), c=jnp.stack(cT), pos=state.pos + step)
    return logits, new_state


def rnn_verify(variables: dict, tokens: Array, cfg: RNNConfig,
               state: RNNState, *, tables: Optional[list] = None,
               live: Optional[Array] = None,
               interpret: Optional[bool] = None):
    """Speculative-decoding target verify: T tokens through the EXACT
    decode-step body, one `lax.scan` (DESIGN.md §9).

    tokens: (B, T) int32.  Returns (logits (B, T, vocab), end RNNState,
    (hs, cs)) where hs/cs are the per-step carried states stacked over time
    ((T, L, B, H)) — the rollback material `rnn_spec_commit` selects from.

    The scan body IS `rnn_decode_step` (fused Pallas kernel and all), so
    position i's logits and state are bit-identical to i+1 sequential
    decode steps — at temperature 0 a verified stream is byte-identical to
    plain decoding, which is the whole speculative contract.  `live` (B,)
    freezes dead continuous-batching rows exactly as in the tick."""
    if tables is None:
        tables = rnn_decode_tables(variables, cfg)

    def body(carry, tok_t):
        lg, ns = rnn_decode_step(variables, tok_t, cfg, carry, tables=tables,
                                 live=live, interpret=interpret)
        return ns, (lg, ns.h, ns.c)

    end, (lgs, hs, cs) = jax.lax.scan(body, state,
                                      jnp.swapaxes(tokens, 0, 1))
    return jnp.swapaxes(lgs, 0, 1), end, (hs, cs)


def rnn_spec_commit(state0: RNNState, emits, n: Array) -> RNNState:
    """Roll a verified/drafted span back to `n` committed tokens per slot.

    emits: (hs, cs) stacked per-step states from `rnn_verify` (or the
    engine's draft loop), shape (T, L, B, H); n: (B,) int32 in [0, T].
    Slot b gets the state after its first n[b] tokens — n = 0 restores
    `state0`'s row bit-for-bit (the reject-everything rollback; also the
    dead-slot no-op), because the O(1) recurrent state needs no byte
    surgery: rollback is a SELECT, not a rewind."""
    hs, cs = emits
    idx = jnp.maximum(n - 1, 0)[:, None, None, None]

    def pick(stack, base):
        sb = jnp.moveaxis(stack, 2, 0)                  # (B, T, L, H)
        sel = jnp.take_along_axis(sb, idx, axis=1)[:, 0]
        sel = jnp.moveaxis(sel, 0, 1)                   # (L, B, H)
        return jnp.where((n > 0)[None, :, None], sel, base)

    return RNNState(h=pick(hs, state0.h), c=pick(cs, state0.c),
                    pos=state0.pos + n)


def lm_loss(variables, tokens, targets, cfg: RNNConfig, *, training, rng=None):
    """Mean next-token cross entropy (nats).  BPC = loss / ln(2)."""
    logits, new_state = rnn_lm_apply(variables, tokens, cfg, training=training,
                                     rng=rng, return_state=True)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll), new_state


def clip_masters(params, cfg: RNNConfig):
    """Post-update clip of master weights to [-alpha, alpha] (keeps Bernoulli
    probabilities valid).  No-op for unquantized configs."""
    if not cfg.quant.enabled:
        return params
    params = dict(params)
    layers = []
    for lp in params["layers"]:
        lp = dict(lp)
        lp["wx"] = Q.clip_master(lp["wx"], Q.glorot_alpha(*lp["wx"].shape))
        lp["wh"] = Q.clip_master(lp["wh"], Q.glorot_alpha(*lp["wh"].shape))
        layers.append(lp)
    params["layers"] = layers
    return params
