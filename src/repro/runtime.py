"""Process-wide runtime context: which logical mesh axes exist right now.

Model code calls `constrain(x, *axes)` to request activation shardings; outside
a mesh context (unit tests, single-device runs) this is a no-op, inside the
dry-run / trainer it becomes `with_sharding_constraint`.  Axes that do not
divide the corresponding dimension are dropped (e.g. 8 KV heads on a 16-way
'model' axis -> replicated), so one set of rules serves every (arch x mesh).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def current_mesh() -> Optional[jax.sharding.Mesh]:
    return getattr(_state, "mesh", None)


def current_param_rules():
    return getattr(_state, "param_rules", None)


def current_compute_rules():
    return getattr(_state, "compute_rules", None)


def abstract_mesh(sizes: Sequence[int], names: Sequence[str]):
    """Device-free mesh for spec-building and tests, across jax versions:
    jax >= 0.5 takes AbstractMesh(axis_sizes, axis_names); 0.4.x takes one
    tuple of (name, size) pairs.  Same OrderedDict shape either way."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(sizes), tuple(names))
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))


@contextlib.contextmanager
def use_mesh(mesh: Optional[jax.sharding.Mesh], param_rules=None,
             compute_rules=None):
    """`param_rules(path, leaf, mesh) -> PartitionSpec` (optional) lets inner
    code (e.g. the quantizer) pin intermediates to the parameter STORAGE
    layout; `compute_rules` gives the layout of the transient COMPUTE copy
    (bf16 / unpacked weights) that the matmuls consume — see constrain_param."""
    prev = current_mesh()
    prev_rules = current_param_rules()
    prev_crules = current_compute_rules()
    _state.mesh = mesh
    _state.param_rules = param_rules
    _state.compute_rules = compute_rules
    # AbstractMesh (tests / spec-building) is not a context manager
    is_concrete = isinstance(mesh, jax.sharding.Mesh)
    ctx = mesh if is_concrete else contextlib.nullcontext()
    try:
        with ctx:
            yield mesh
    finally:
        _state.mesh = prev
        _state.param_rules = prev_rules
        _state.compute_rules = prev_crules


def constrain_param(path, master: jax.Array, derived: jax.Array,
                    drop_axes: Sequence[str] = (),
                    kind: str = "storage") -> jax.Array:
    """Constrain `derived` (e.g. a quantized weight) to the sharding the
    parameter rules give `master`.  This forces elementwise work (stochastic
    quantization, bf16 cast, bit packing) to run shard-local, so the FSDP
    all-gather moves the small derived tensor instead of fp32 masters.

    `drop_axes` removes mesh axes from the spec (replicating those dims) —
    used to place the UNPACKED weight after a packed gather: packed is
    (data, model)-sharded, unpacked is model-only, so the SPMD reshard
    (the all-gather over 'data') happens on the 2-bit codes."""
    mesh, rules = current_mesh(), current_param_rules()
    if kind == "compute" and current_compute_rules() is not None:
        rules = current_compute_rules()
        drop_axes = ()
    if mesh is None or rules is None:
        return derived
    spec = rules(path, master, mesh)
    if drop_axes:
        def keep(a):
            if a is None:
                return None
            if isinstance(a, tuple):
                kept = tuple(x for x in a if x not in drop_axes)
                return kept if len(kept) > 1 else (kept[0] if kept else None)
            return None if a in drop_axes else a
        spec = jax.sharding.PartitionSpec(*[keep(a) for a in spec])
    # rank matches (packed keeps rank; K/GROUP axis reuses K's spec) but the
    # packed dim may no longer divide — drop axes that don't fit.
    entries = list(tuple(spec)[: derived.ndim])
    entries += [None] * (derived.ndim - len(entries))
    fixed = []
    for dim, a in zip(derived.shape, entries):
        if a is None:
            fixed.append(None)
            continue
        axes = a if isinstance(a, tuple) else (a,)
        n = 1
        for x in axes:
            n *= mesh.shape.get(x, 1)
        fixed.append(a if dim % n == 0 else None)
    spec = jax.sharding.PartitionSpec(*fixed)
    return jax.lax.with_sharding_constraint(
        derived, jax.sharding.NamedSharding(mesh, spec))


def _fit(dim: int, axes, mesh) -> Optional[object]:
    """Return the largest prefix of `axes` whose product divides `dim`."""
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    keep = []
    prod = 1
    for a in axes:
        if a not in mesh.shape:
            continue
        n = mesh.shape[a]
        if dim % (prod * n) == 0:
            keep.append(a)
            prod *= n
        else:
            break
    if not keep:
        return None
    return tuple(keep) if len(keep) > 1 else keep[0]


def spec_for(shape: Sequence[int], *axes) -> P:
    """Build a PartitionSpec, silently replicating non-divisible dims."""
    mesh = current_mesh()
    if mesh is None:
        return P()
    return P(*[_fit(d, a, mesh) for d, a in zip(shape, axes)])


def constrain(x: jax.Array, *axes) -> jax.Array:
    """Sharding-constrain x per logical axes; no-op outside a mesh context."""
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec_for(x.shape, *axes))
