"""Unified recurrent serving runtime (DESIGN.md §6).

One stateful prefill/decode interface over every decoder in the repo — the
paper's BN-LSTM/BN-GRU, RWKV6, Mamba2, and the attention families:

    rt = serving_runtime(cfg, params)          # RNNConfig or ModelConfig
    state = rt.init_state(batch, context)
    logits, state = rt.prefill(tokens, state)  # (B, V) last-token logits
    logits, state = rt.decode_step(tok, state) # tok: (B,) int32

`state` is an opaque pytree the caller threads, never inspects:

  * BN-LSTM/GRU — `bnlstm.RNNState` (stacked per-layer h/c).  The runtime
    builds the per-session decode tables ONCE (frozen-BN affines, the
    dequantized+BN-folded layer-0 row table, the stacked whole-tick kernel
    artifact) and passes them into the jitted step, so a packed tree
    decodes through ONE fused Pallas launch per tick with no per-call
    re-preparation — or, on CPU, through dense fp tables (backend-honest
    dispatch, kernels/dispatch.py).
  * transformer pool — the `T.init_caches` pytree.  For RWKV6 / Mamba2
    layers the cache slots hold `RWKVState` / `SSMState` and the decode step
    runs `wkv6_step` / `ssd_step`; attention layers hold KV caches in the
    same slots.  The runtime treats both identically.

The launcher (`launch/serve.py`), the `serve_decode` benchmark and the
serving tests all drive this interface, so every arch exercises the same
prefill → sample → decode loop.
"""
from __future__ import annotations

import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bnlstm as BL
from repro.core.qtensor import tree_nbytes
from repro.configs.shapes import decode_context
from repro.models import transformer as T
from repro.serve.sampler import sample

Array = jax.Array


def state_nbytes(state: Any) -> int:
    """Bytes a session's recurrent state occupies (KV caches / S-matrices /
    h,c vectors alike) — the per-session memory a serving fleet provisions."""
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(state)
               if hasattr(l, "dtype"))


class RNNRuntime:
    """BN-LSTM / BN-GRU serving session (core/bnlstm.py serving entry)."""

    family = "rnn"
    # chunked in-slot prefill (DESIGN.md §8): the O(1) recurrent carry makes
    # any split exact, and the masked scan makes bucket padding exact — the
    # engine compiles one prefill trace per power-of-two bucket, ever.
    chunk_granularity = "token"
    pad_buckets = True
    # speculative decoding (DESIGN.md §9): verify is a scan of the exact
    # decode-step body, rollback a per-step-state SELECT — always exact.
    spec_capable = True

    def __init__(self, cfg: BL.RNNConfig, variables: dict, *,
                 interpret: Optional[bool] = None,
                 dense_tables: Optional[bool] = None):
        from repro.kernels import dispatch

        self.cfg = cfg
        self.variables = variables
        self._interpret = interpret
        # dense_tables=None lets kernels/dispatch.py pick the backend-honest
        # path: dense fp tables on CPU (no interpret-mode Pallas in serving),
        # packed tables + the whole-tick fused kernel on tpu/gpu.  Parity
        # tests opt into packed-on-CPU with dense_tables=False +
        # interpret=True.
        self._dense_tables = dispatch.prefer_dense(dense_tables)
        # once per session: dequantized layer-0 rows, BN affines, and (when
        # packed) the stacked whole-tick kernel artifact — see
        # rnn_decode_tables
        self.tables = BL.rnn_decode_tables(variables, cfg,
                                           dense=self._dense_tables)
        def prefill_last(v, tb, toks, st):
            # take the last-token logits from the carried state through the
            # shared (B, 1, H) head (rnn_logits_last): XLA never
            # materializes the (B, T, vocab) prompt logits the serving loop
            # discards, and the chunked engine's first-token sample — which
            # uses the same helper — is bit-identical to this one
            _, st = BL.rnn_prefill(v, toks, cfg, st, tables=tb)
            return BL.rnn_logits_last(v, st, cfg), st

        self._prefill = jax.jit(prefill_last)
        self._decode = jax.jit(
            lambda v, tb, tok, st: BL.rnn_decode_step(
                v, tok, cfg, st, tables=tb, interpret=interpret))

    def init_state(self, batch: int, context: int = 0, *,
                   per_slot: bool = False) -> BL.RNNState:
        del context  # constant-size state: the RNN's whole point
        return BL.rnn_state_init(self.cfg, batch, per_slot=per_slot)

    def prefill(self, tokens: Array, state: BL.RNNState):
        return self._prefill(self.variables, self.tables, tokens, state)

    def decode_step(self, tok: Array, state: BL.RNNState):
        return self._decode(self.variables, self.tables, tok, state)

    @property
    def jit_prm(self):
        """The pytree a caller jitting its own region must thread as an
        ARGUMENT into `decode_fn`/`prefill_chunk`/`verify` (the engine's
        tick/prefill jits do): closing over weights instead lets XLA
        constant-fold them, which shifts logits ~1ulp vs the arg-passed
        `drive_session` jits and makes logits-level comparisons unsound."""
        return (self.variables, self.tables)

    def serve_prm_shardings(self, mesh):
        """Mesh placement of `jit_prm` for a sharded ServeEngine: fully
        REPLICATED.  The fused (H, 4H) gate weight cannot column-shard over
        'model' without splitting the i/f/g/o gates across shards (the
        `split(4)` boundary lands mid-axis), which would turn the f*c + i*g
        elementwise math into cross-shard traffic — and at paper scale the
        packed LSTM is a few hundred KB, so replication is the right call.
        Data-sharding of the slot pool is untouched by this: rows of the
        tick read replicated weights shard-locally."""
        from jax.sharding import NamedSharding, PartitionSpec
        rep = NamedSharding(mesh, PartitionSpec())
        return jax.tree.map(lambda _: rep, self.jit_prm)

    def decode_fn(self, tok: Array, state: BL.RNNState,
                  live: Optional[Array] = None, prm=None):
        """Unjitted decode body for callers that jit a larger region (the
        continuous-batching engine's tick).  `live` (B,) masks dead slots:
        their h/c/pos stay bit-for-bit frozen inside the fused kernel.
        `prm` is the caller's traced `jit_prm` (None: close over self's —
        only sound outside jit)."""
        var, tb = prm if prm is not None else (self.variables, self.tables)
        return BL.rnn_decode_step(var, tok, self.cfg, state,
                                  tables=tb, live=live,
                                  interpret=self._interpret)

    def prefill_chunk(self, tokens: Array, state: BL.RNNState, n: Array,
                      prm=None):
        """Unjitted bucket-padded chunk body (engine jits gather+chunk+write
        as one region): consume the first `n` of tokens, carry the state."""
        var, tb = prm if prm is not None else (self.variables, self.tables)
        return BL.rnn_prefill_chunk(var, tokens, self.cfg, state,
                                    n=n, tables=tb)

    def write_slots(self, state: BL.RNNState, sub: BL.RNNState, slots):
        return BL.rnn_write_slots(state, sub, slots)

    # -- speculative decoding (DESIGN.md §9) --------------------------------
    # The RNN's rollback story is the O(1) state again: every step of a
    # draft/verify span EMITS its (h, c) carry, and committing n tokens is a
    # per-slot select over the emitted stack — no byte surgery, n = 0 IS the
    # pre-span state.  spec_snapshot therefore has nothing to save.

    def spec_snapshot(self, state: BL.RNNState, span: int):
        del state, span
        return ()

    def spec_emit(self, state: BL.RNNState):
        """Per-step rollback material emitted inside the engine's draft
        scan: the carried h/c stacks (pos is recomputed at commit)."""
        return (state.h, state.c)

    def verify(self, tokens: Array, state: BL.RNNState,
               live: Optional[Array] = None, prm=None):
        """Multi-token target step (unjitted body — the engine jits the
        whole spec tick): (B, T) tokens -> (logits (B, T, V), end state,
        per-step emits).  Bit-identical per position to T decode steps."""
        var, tb = prm if prm is not None else (self.variables, self.tables)
        return BL.rnn_verify(var, tokens, self.cfg, state,
                             tables=tb, live=live,
                             interpret=self._interpret)

    def spec_commit(self, state0: BL.RNNState, state_after: BL.RNNState,
                    snap, emits, n: Array) -> BL.RNNState:
        del state_after, snap
        return BL.rnn_spec_commit(state0, emits, n)

    def param_nbytes(self) -> tuple[int, int]:
        return tree_nbytes(self.variables["params"])


class TransformerRuntime:
    """Transformer-pool serving session — includes the recurrent members
    (rwkv6-7b, zamba2-1.2b), whose decode steps are `wkv6_step`/`ssd_step`
    carried inside the cache pytree."""

    family = "transformer"

    def __init__(self, cfg, params, *, extras: Optional[dict] = None):
        self.cfg = cfg
        self.params = params
        self.extras = dict(extras or {})
        self._prefill = jax.jit(
            lambda p, t, c: T.prefill(p, t, c, cfg, **self.extras))
        self._decode = jax.jit(lambda p, t, c: T.decode_step(p, t, c, cfg))
        # chunked in-slot prefill policy (DESIGN.md §8).  Splitting a prompt
        # mid-sequence is byte-exact only when every layer's math is
        # per-token given the cache: recurrent mixers (rwkv/mamba) re-chunk
        # their internal scans at different boundaries and MoE capacity
        # competition spans the whole slice, so those archs prefill the
        # prompt as ONE in-slot chunk.  Bucket PADDING additionally requires
        # that pad writes land past the rewound pos in a non-ring cache —
        # sliding-window rings recycle those slots, so they chunk exactly.
        pat, rep, tail = T.expand_pattern(cfg)
        kinds = set(pat) | set(tail)
        whole = bool(kinds & {"mamba", "rwkv"}) or cfg.n_experts > 0
        self.chunk_granularity = "whole" if whole else "token"
        self.pad_buckets = (not whole) and not cfg.swa_all and \
            "local" not in kinds
        # speculative decoding needs (a) a multi-token step that is
        # per-token exact (token granularity: rules out MoE capacity
        # competition and rwkv/mamba internal scan re-chunking) and (b)
        # non-ring caches so a rejected suffix can be rolled back without
        # having recycled in-window history — exactly the pad_buckets
        # predicate.
        self.spec_capable = self.chunk_granularity == "token" and \
            self.pad_buckets

    def init_state(self, batch: int, context: int, *,
                   per_slot: bool = False):
        _, src = decode_context(self.cfg, context)
        return T.init_caches(self.cfg, batch, context, src_len=src,
                             dtype=jnp.dtype(self.cfg.dtype),
                             per_slot=per_slot)

    def prefill(self, tokens: Array, state):
        return self._prefill(self.params, tokens, state)

    def decode_step(self, tok: Array, state):
        return self._decode(self.params, tok, state)

    @property
    def jit_prm(self):
        """The param tree a caller's own jit must thread as an argument (see
        RNNRuntime.jit_prm — same constant-folding rationale)."""
        return self.params

    def serve_prm_shardings(self, mesh):
        """Mesh placement of `jit_prm` for a sharded ServeEngine: the
        name-based serving rules (tensor-parallel over 'model', no FSDP
        axis), with packed QTensor leaves projected onto their codes —
        column-parallel Wq/Wk/Wv/Wup shard the codes' output-column axis
        directly, row-parallel Wo/Wdown shard the packed rows when the pack
        group divides cleanly.  This is how the large configs serve at
        size: each model shard holds 1/M of every weight's codes."""
        from repro.launch.sharding import serve_param_shardings
        return serve_param_shardings(self.params, mesh)

    def decode_fn(self, tok: Array, state, live: Optional[Array] = None,
                  prm=None):
        """Unjitted decode body for callers that jit a larger region (the
        continuous-batching engine's tick).  `live` (B,) freezes dead rows'
        cache writes and recurrent states bit-for-bit — with in-slot
        chunked prefill a dead row can be a slot MID-PREFILL, so the old
        zombie-writes-are-harmless argument no longer holds.  Dead rows'
        logits stay garbage; the engine never samples them."""
        p = prm if prm is not None else self.params
        return T.decode_step(p, tok, state, self.cfg, live=live)

    def prefill_chunk(self, tokens: Array, state, n: Array, prm=None):
        """Unjitted prompt-chunk body (engine jits gather+chunk+write as one
        region): consume the first `n` of tokens against the carried cache;
        bucket padding past `n` is rewound off the attention pos."""
        p = prm if prm is not None else self.params
        return T.prefill(p, tokens, state, self.cfg, n=n)

    # -- speculative decoding (DESIGN.md §9) --------------------------------
    # Rollback here is byte surgery on the caches: snapshot the span of
    # k/v bytes a draft/verify is about to overwrite, and commit restores
    # the rejected suffix and rewinds each slot's pos — the committed cache
    # is bit-identical to one that only ever saw the accepted prefix.

    def _is_cache(self, x) -> bool:
        from repro.serve.kvcache import AttnCache
        return isinstance(x, AttnCache)

    def spec_snapshot(self, state, span: int):
        from repro.serve.kvcache import cache_spec_snapshot
        return jax.tree.map(lambda c: cache_spec_snapshot(c, span),
                            state, is_leaf=self._is_cache)

    def spec_emit(self, state):
        del state  # the snapshot carries all rollback material
        return ()

    def verify(self, tokens: Array, state, live: Optional[Array] = None,
               prm=None):
        """Multi-token target step (unjitted body — the engine jits the
        whole spec tick): (B, T) tokens -> (logits (B, T, V), caches, ()).
        Per-position logits through the decode head shape; bit-identical
        per position to T decode steps (tests/test_spec_decode.py)."""
        p = prm if prm is not None else self.params
        logits, state = T.verify_step(p, tokens, state, self.cfg,
                                      live=live)
        return logits, state, ()

    def spec_commit(self, state0, state_after, snap, emits, n: Array):
        del state0, emits
        from repro.serve.kvcache import cache_spec_commit
        return jax.tree.map(lambda c, s: cache_spec_commit(c, s, n),
                            state_after, snap, is_leaf=self._is_cache)

    def param_nbytes(self) -> tuple[int, int]:
        return tree_nbytes(self.params)


def serving_runtime(cfg, params, **kw):
    """The one constructor: RNNConfig -> RNNRuntime (params is the
    {'params', 'state'} variables dict), ModelConfig -> TransformerRuntime."""
    if isinstance(cfg, BL.RNNConfig):
        return RNNRuntime(cfg, params, **kw)
    return TransformerRuntime(cfg, params, **kw)


def speculative_draft(rt, mode: str = "ternary",
                      dense: Optional[bool] = None):
    """Self-speculation pairing (DESIGN.md §9): pack the target runtime's
    OWN master weights into a binary/ternary draft runtime.

    The paper's whole hardware argument — packed weights decode ~10x faster
    in ~12x less memory — is the profile of an ideal draft model, and
    because the draft is a QTensor export of the very tree the fp target
    serves, the two track closely and acceptance stays high.  The returned
    runtime shares the target's config dims (and, for the RNN, its frozen
    BN statistics), so the engine can drive both pools through identical
    prefill plans.

    `dense` (RNN drafts): expand the packed weights into dense decode
    tables once per session.  None defers to the backend dispatch policy
    (kernels/dispatch.py): dense on CPU, where the draft's job is raw step
    latency and packed Pallas would only run emulated; on real accelerators
    the draft keeps the whole-tick fused packed kernel."""
    import dataclasses

    from repro.core.qtensor import export_packed, is_qtensor
    from repro.core.quantize import QuantSpec

    if isinstance(rt, RNNRuntime):
        wx0 = rt.variables["params"]["layers"][0]["wx"]
        if is_qtensor(wx0):
            raise ValueError(
                "speculative pairing packs the target's fp masters; this "
                "runtime already serves a packed tree — build the pair "
                "from the master weights instead")
        dcfg = dataclasses.replace(
            rt.cfg, quant=QuantSpec(mode=mode, norm="batch"))
        packed = BL.export_packed_rnn(rt.variables["params"], dcfg)
        return RNNRuntime(dcfg, {"params": packed,
                                 "state": rt.variables["state"]},
                          interpret=rt._interpret, dense_tables=dense)
    if any(is_qtensor(l) for l in jax.tree_util.tree_leaves(
            rt.params, is_leaf=is_qtensor)):
        raise ValueError(
            "speculative pairing packs the target's fp masters; this "
            "runtime already serves a packed tree — build the pair from "
            "the master weights instead")
    dcfg = rt.cfg.with_quant(QuantSpec(mode=mode, norm="channel"))
    return TransformerRuntime(dcfg, export_packed(rt.params, dcfg.quant),
                              extras=dict(rt.extras))


def drive_session(rt, prompt: Array, vocab: int, *, gen: int,
                  temperature: float = 0.8, top_k: int = 0, seed: int = 0,
                  warmup: bool = False, context: Optional[int] = None):
    """The canonical prefill -> sample -> decode session, timed.

    One implementation drives the launcher AND the serve_decode benchmark,
    so the benchmark measures exactly the loop production runs.  With
    `warmup` an untimed prefill + decode step runs first, so the recorded
    tok/s measures the serving path rather than jit tracing/compilation.

    `context` overrides the provisioned context length (default: exactly
    S + gen).  The engine parity tests pass the engine pool's max_context so
    the sequential baseline attends over an identically-sized cache.

    Returns (generated (B, gen) int array, metrics dict with prefill/decode
    seconds, tok/s, and the per-session state bytes)."""
    B, S = prompt.shape
    context = context or (S + gen)
    if warmup:
        # warmup owns its OWN state; the timed run below starts from a fresh
        # init_state, so warmup can never leak a prefilled state (or retain
        # st_w's memory) into the measurement
        st_w = rt.init_state(B, context)
        lg_w, st_w = rt.prefill(prompt, st_w)
        nxt_w = sample(lg_w, jax.random.PRNGKey(0), temperature=temperature,
                       top_k=top_k, vocab=vocab)
        jax.block_until_ready(rt.decode_step(nxt_w, st_w)[0])
        del lg_w, st_w, nxt_w

    state = rt.init_state(B, context)
    # clean-state invariant: every position counter of a fresh state is 0
    # (the float leaves are zeros by construction; pos is what warmup could
    # plausibly have threaded through)
    assert all(int(jnp.sum(l)) == 0
               for l in jax.tree_util.tree_leaves(state)
               if hasattr(l, "dtype") and jnp.issubdtype(l.dtype, jnp.integer))

    t0 = time.perf_counter()
    logits, state = rt.prefill(prompt, state)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    toks = []
    key = jax.random.PRNGKey(seed)
    t0 = time.perf_counter()
    for _ in range(gen):
        key, sk = jax.random.split(key)
        nxt = sample(logits, sk, temperature=temperature, top_k=top_k,
                     vocab=vocab)
        # accumulate ON DEVICE: np.asarray here would block on the transfer
        # every iteration and the recorded decode tok/s would measure host
        # round-trips, not the serving path
        toks.append(nxt)
        logits, state = rt.decode_step(nxt, state)
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    out = np.stack([np.asarray(t) for t in toks], axis=1)
    metrics = {
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "prefill_tok_s": B * S / t_prefill,
        "decode_tok_s": B * gen / t_decode,
        "state_nbytes": state_nbytes(state),
    }
    return out, metrics
