"""Unified recurrent serving runtime (DESIGN.md §6).

One stateful prefill/decode interface over every decoder in the repo — the
paper's BN-LSTM/BN-GRU, RWKV6, Mamba2, and the attention families:

    rt = serving_runtime(cfg, params)          # RNNConfig or ModelConfig
    state = rt.init_state(batch, context)
    logits, state = rt.prefill(tokens, state)  # (B, V) last-token logits
    logits, state = rt.decode_step(tok, state) # tok: (B,) int32

`state` is an opaque pytree the caller threads, never inspects:

  * BN-LSTM/GRU — `bnlstm.RNNState` (stacked per-layer h/c).  The runtime
    builds the per-session decode tables ONCE (frozen-BN affines, the
    dequantized+BN-folded layer-0 row table, gate-aligned packed codes) and
    passes them into the jitted step, so a packed tree decodes through the
    fused Pallas step kernel with no per-call re-preparation.
  * transformer pool — the `T.init_caches` pytree.  For RWKV6 / Mamba2
    layers the cache slots hold `RWKVState` / `SSMState` and the decode step
    runs `wkv6_step` / `ssd_step`; attention layers hold KV caches in the
    same slots.  The runtime treats both identically.

The launcher (`launch/serve.py`), the `serve_decode` benchmark and the
serving tests all drive this interface, so every arch exercises the same
prefill → sample → decode loop.
"""
from __future__ import annotations

import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bnlstm as BL
from repro.core.qtensor import tree_nbytes
from repro.configs.shapes import decode_context
from repro.models import transformer as T
from repro.serve.sampler import sample

Array = jax.Array


def state_nbytes(state: Any) -> int:
    """Bytes a session's recurrent state occupies (KV caches / S-matrices /
    h,c vectors alike) — the per-session memory a serving fleet provisions."""
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(state)
               if hasattr(l, "dtype"))


class RNNRuntime:
    """BN-LSTM / BN-GRU serving session (core/bnlstm.py serving entry)."""

    family = "rnn"

    def __init__(self, cfg: BL.RNNConfig, variables: dict, *,
                 interpret: Optional[bool] = None):
        self.cfg = cfg
        self.variables = variables
        # once per session: dequantized layer-0 rows, BN affines, gate codes
        self.tables = BL.rnn_decode_tables(variables, cfg)
        def prefill_last(v, tb, toks, st):
            # slice to the last position INSIDE jit so XLA never materializes
            # the (B, T, vocab) prompt logits the serving loop discards
            logits, st = BL.rnn_prefill(v, toks, cfg, st, tables=tb)
            return logits[:, -1], st

        self._prefill = jax.jit(prefill_last)
        self._decode = jax.jit(
            lambda v, tb, tok, st: BL.rnn_decode_step(
                v, tok, cfg, st, tables=tb, interpret=interpret))

    def init_state(self, batch: int, context: int = 0) -> BL.RNNState:
        del context  # constant-size state: the RNN's whole point
        return BL.rnn_state_init(self.cfg, batch)

    def prefill(self, tokens: Array, state: BL.RNNState):
        return self._prefill(self.variables, self.tables, tokens, state)

    def decode_step(self, tok: Array, state: BL.RNNState):
        return self._decode(self.variables, self.tables, tok, state)

    def param_nbytes(self) -> tuple[int, int]:
        return tree_nbytes(self.variables["params"])


class TransformerRuntime:
    """Transformer-pool serving session — includes the recurrent members
    (rwkv6-7b, zamba2-1.2b), whose decode steps are `wkv6_step`/`ssd_step`
    carried inside the cache pytree."""

    family = "transformer"

    def __init__(self, cfg, params, *, extras: Optional[dict] = None):
        self.cfg = cfg
        self.params = params
        self.extras = dict(extras or {})
        self._prefill = jax.jit(
            lambda p, t, c: T.prefill(p, t, c, cfg, **self.extras))
        self._decode = jax.jit(lambda p, t, c: T.decode_step(p, t, c, cfg))

    def init_state(self, batch: int, context: int):
        _, src = decode_context(self.cfg, context)
        return T.init_caches(self.cfg, batch, context, src_len=src,
                             dtype=jnp.dtype(self.cfg.dtype))

    def prefill(self, tokens: Array, state):
        return self._prefill(self.params, tokens, state)

    def decode_step(self, tok: Array, state):
        return self._decode(self.params, tok, state)

    def param_nbytes(self) -> tuple[int, int]:
        return tree_nbytes(self.params)


def serving_runtime(cfg, params, **kw):
    """The one constructor: RNNConfig -> RNNRuntime (params is the
    {'params', 'state'} variables dict), ModelConfig -> TransformerRuntime."""
    if isinstance(cfg, BL.RNNConfig):
        return RNNRuntime(cfg, params, **kw)
    return TransformerRuntime(cfg, params, **kw)


def drive_session(rt, prompt: Array, vocab: int, *, gen: int,
                  temperature: float = 0.8, top_k: int = 0, seed: int = 0,
                  warmup: bool = False):
    """The canonical prefill -> sample -> decode session, timed.

    One implementation drives the launcher AND the serve_decode benchmark,
    so the benchmark measures exactly the loop production runs.  With
    `warmup` an untimed prefill + decode step runs first, so the recorded
    tok/s measures the serving path rather than jit tracing/compilation.

    Returns (generated (B, gen) int array, metrics dict with prefill/decode
    seconds, tok/s, and the per-session state bytes)."""
    B, S = prompt.shape
    state = rt.init_state(B, S + gen)
    if warmup:
        lg_w, st_w = rt.prefill(prompt, state)
        nxt_w = sample(lg_w, jax.random.PRNGKey(0), temperature=temperature,
                       top_k=top_k, vocab=vocab)
        jax.block_until_ready(rt.decode_step(nxt_w, st_w)[0])

    t0 = time.perf_counter()
    logits, state = rt.prefill(prompt, state)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    toks = []
    key = jax.random.PRNGKey(seed)
    t0 = time.perf_counter()
    for _ in range(gen):
        key, sk = jax.random.split(key)
        nxt = sample(logits, sk, temperature=temperature, top_k=top_k,
                     vocab=vocab)
        toks.append(np.asarray(nxt))
        logits, state = rt.decode_step(nxt, state)
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    out = np.stack(toks, axis=1)
    metrics = {
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "prefill_tok_s": B * S / t_prefill,
        "decode_tok_s": B * gen / t_decode,
        "state_nbytes": state_nbytes(state),
    }
    return out, metrics
