"""Continuous-batching serve engine (DESIGN.md §7-§10).

`ServeEngine` owns a fixed pool of B slots over any serving runtime
(BN-LSTM/GRU, RWKV6, Mamba2-hybrid, attention archs) and turns the lockstep
prefill→decode loop into mixed-length traffic serving:

  * requests are ADMITTED from a queue as slots free up — admission is pure
    bookkeeping: the prompt is split into fixed-size, bucket-padded CHUNKS
    and the slot enters a `prefilling` phase;
  * each scheduler iteration runs AT MOST ONE prefill chunk, straight into
    the admitted slot (gather the slot row, run the resumable chunk, write
    the row back), interleaved with the batched decode tick — a long prompt
    can never stall live decodes for more than one chunk's worth of work
    (Sarathi/SplitFuse-style, adapted to the mask-don't-reshape pool);
  * every tick runs ONE batched `decode_step` across all B slots with dead
    slots MASKED, never resliced — the tick's operand shapes are
    occupancy-independent, so jit traces the decode path exactly once and
    admit/retire between ticks cannot retrace it (asserted in tests);
    prefilling slots are dead for the tick, and the runtimes freeze dead
    rows' state bit-for-bit (a dead row may be mid-prefill);
  * a request's FIRST token is sampled when its last chunk lands —
    `Completion.t_first` is the real first-token time, not the admission
    time — then the slot turns live and decodes;
  * slots RETIRE on EOS or per-request max-tokens and are immediately
    reusable; freed slots are scrubbed in one batched shape-aware reset
    (recurrent leaves and positions to zero, attention KV masked in place)
    because the next occupant's prefill RESUMES from the slot row.

The scheduler is driven through a RESUMABLE step API (DESIGN.md §10):
`submit()` enqueues a request (priority/SLO-ordered admission), `step()`
runs ONE scheduler iteration and returns the tokens it sampled plus any
completions, and `cancel(rid)` retires an in-flight request mid-stream —
mid-prefill or mid-decode — through the same batched scrub retirement
uses, so a hung-up client leaks nothing into the slot's next occupant.
`run()` is a thin loop over submit/step (byte-identical to the pre-step-API
batch driver); the asyncio front door (serve/frontdoor.py) drives step()
from an event loop while requests arrive and die asynchronously.

With a `PrefixCache` (serve/prefixcache.py) attached, admission splices the
longest cached prompt prefix straight into the slot instead of re-prefilling
it — for the RNN family that is ONE (L, H) row-pair copy, the O(1)-state
advantage the paper's hardware pitch implies — and every full prefill chunk
that lands offers the carried slot state back to the cache at its
chunk-boundary offset.

Sampling is per-slot vectorized (serve/sampler.sample_slots): each slot
carries its own temperature / top-k / PRNG key chain, and a slot's draws are
bit-identical to running that request alone through `drive_session` — the
engine changes the schedule, not the tokens.

Speculative decoding (DESIGN.md §9) replaces the tick with a
draft-verify-accept round: a packed binary/ternary DRAFT runtime (its own
slot pool, prefilled and scrubbed in lockstep) proposes `spec_k` tokens per
live slot, the target verifies them all in one multi-token step, and
rejection sampling commits each slot's accepted prefix — the output
distribution is exactly the target's, byte-identical to plain decoding at
temperature 0.  Rollback of rejected suffixes reuses the slot surgery:
per-step state SELECT for RNN families, KV suffix byte-restore + pos
rewind for attention.

Every jitted region takes the runtime's parameter tree as an ARGUMENT
(`rt.jit_prm`) instead of closing over it: closed-over weights get
constant-folded, which shifts logits ~1ulp against the arg-passed
`drive_session` jits and makes logits-level comparisons unsound.  Passing
the same pytree every call leaves the trace count at 1 (asserted lifelong).
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.sampler import sample_slots, spec_accept

Array = jax.Array


@dataclasses.dataclass
class Request:
    """One generation request.  `arrival_s` is the submit time relative to
    engine start (0 = already queued) — the traffic replay sets it from a
    Poisson process; latency is measured against it.

    `priority` orders ADMISSION (lower = admitted sooner; ties fall back to
    arrival time, then submit order).  Admission is preemption-free: a
    running low-priority request is never evicted, a queued one is only
    overtaken.  `slo` is a reporting label — per-class TTFT percentiles are
    broken out in the run metrics so deadline classes can be provisioned
    separately."""

    prompt: Any                  # (S,) int token ids (list / np / jnp)
    max_tokens: int
    temperature: float = 0.8
    top_k: int = 0
    seed: int = 0
    arrival_s: float = 0.0
    priority: int = 0
    slo: str = "default"
    rid: Optional[int] = None    # engine numbers submissions when None (the
                                 # Request object itself is never mutated)


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: List[int]            # sampled ids, EOS included when hit
    prompt_len: int
    finished: str                # 'length' | 'eos' | 'cancelled'
    slot: int
    t_submit: float              # engine-relative seconds
    t_admit: float               # slot allocated; prefill starts after this
    t_first: float               # the FIRST token was actually sampled (the
                                 # prompt's last chunk landed) — real TTFT
    t_done: float
    cached_tokens: int = 0       # prompt tokens a prefix-cache splice skipped
    slo: str = "default"

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit

    @property
    def queue_s(self) -> float:
        return self.t_admit - self.t_submit

    @property
    def ttft_s(self) -> float:
        return self.t_first - self.t_submit


@dataclasses.dataclass
class _Active:
    req: Request
    rid: int            # kept here so the caller's Request is never mutated
    tokens: List[int]
    t_submit: float
    t_admit: float
    t_first: Optional[float]            # stamped when the first token samples
    chunks: Deque[Tuple[np.ndarray, int]]  # remaining (padded chunk, n real)
    prompt: np.ndarray  # the full prompt ids (prefix-cache keys slice it)
    off: int            # real prompt tokens consumed so far (incl. spliced)
    cached: int         # tokens a prefix-cache splice made unnecessary


# ---------------------------------------------------------------------------
# generic slot surgery over state pytrees
# ---------------------------------------------------------------------------


def tree_write_slot(pool, sub, slot):
    """Insert a batch-1 state pytree into row `slot` of every pool leaf.

    Works for any state the runtimes produce — stacked or tail
    AttnCache/SSMState/RWKVState nodes and bare array leaves alike — by
    delegating AttnCache nodes to `kvcache.cache_write_slot` (the one
    attention-cache insert implementation) and everything else to
    `kvcache.write_row`, which recovers the slot axis per leaf from the
    static shapes.  `slot` itself is traced, so one compilation serves
    every admission."""
    from repro.serve.kvcache import AttnCache, cache_write_slot, write_row

    is_cache = lambda x: isinstance(x, AttnCache)
    return jax.tree.map(
        lambda p, s: (cache_write_slot(p, s, slot) if is_cache(p)
                      else write_row(p, s, slot)),
        pool, sub, is_leaf=is_cache)


def tree_gather_slot(pool, ref, slot):
    """Read row `slot` of every pool leaf as a batch-1 state pytree — the
    exact inverse of `tree_write_slot`, and the read half of in-slot chunked
    prefill (gather the slot, run one chunk, write it back).  `ref` is a
    batch-1 template of the pool (arrays or ShapeDtypeStructs); its static
    shapes recover the slot axis per leaf."""
    from repro.serve.kvcache import AttnCache, cache_gather_slot, read_row

    is_cache = lambda x: isinstance(x, AttnCache)
    return jax.tree.map(
        lambda p, r: (cache_gather_slot(p, r, slot) if is_cache(p)
                      else read_row(p, r.shape, slot)),
        pool, ref, is_leaf=is_cache)


def tree_reset_slots(pool, ref, mask):
    """Scrub slots where `mask` (B,) is True, shape-aware via the batch-1
    template `ref`: recurrent leaves (h/c, S-matrices, conv tails, shift
    buffers) and every position counter drop to ZERO along the recovered
    slot axis; AttnCache nodes keep their KV bytes and reset only pos
    (stale entries read as unwritten — mask-don't-reshape).  A freed slot
    must read exactly like a fresh one: the next occupant's chunked prefill
    RESUMES from the slot row."""
    from repro.serve.kvcache import (AttnCache, _slot_axis, cache_reset_slots)

    is_cache = lambda x: isinstance(x, AttnCache)

    def scrub(p, r):
        if is_cache(p):
            return cache_reset_slots(p, mask)
        ax = _slot_axis(p.shape, r.shape)
        z = jnp.zeros((), p.dtype)
        if ax is None:  # 1-slot pool: the whole leaf belongs to slot 0
            return jnp.where(mask[0], z, p)
        m = mask.reshape((1,) * ax + (-1,) + (1,) * (p.ndim - ax - 1))
        return jnp.where(m, z, p)

    return jax.tree.map(scrub, pool, ref, is_leaf=is_cache)


def _bucket(n: int, cap: int) -> int:
    """Smallest power-of-two >= n, capped at the chunk size — the static
    prefill shapes, so trace count is O(log chunk), not O(#prompt lengths)."""
    b = 1
    while b < n and b < cap:
        b <<= 1
    return min(b, cap)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


class ServeEngine:
    """Slotted continuous-batching scheduler over one serving runtime.

    eng = ServeEngine(rt, vocab, slots=8, max_context=512, prefill_chunk=32)
    completions, metrics = eng.run(requests)

    or, resumably (the front door's driving mode):

    rid = eng.submit(Request(...))
    while eng.has_work():
        token_events, completions = eng.step()   # [(rid, [ids])], [Completion]
    eng.cancel(rid)                              # any time, any phase

    Speculative mode (DESIGN.md §9) pairs the target with a packed draft:

    eng = ServeEngine(rt, vocab, slots=8, max_context=512,
                      draft=speculative_draft(rt), spec_k=4)

    Mesh mode (DESIGN.md §12) scales the same engine across devices —
    slot pool data-parallel (N× slots, one tick per mesh), weights
    tensor-parallel per the runtime's serving rules:

    eng = ServeEngine(rt, vocab, slots=32, max_context=512,
                      mesh=make_serve_mesh("data=4,model=2"))

    Invariants (DESIGN.md §7-§10, §12):
      * mask-don't-reshape — the pool state, the token/key/temperature
        arrays and therefore the jitted tick keep shape (B, ...) forever;
        occupancy lives in a boolean mask;
      * one trace — `tick_traces` counts jit traces of the decode tick and
        stays at 1 across arbitrary submit/cancel/admit/retire
        interleavings (in spec mode `spec_traces` counts the
        draft-verify-accept round the same way); `prefill_traces` counts
        chunk-prefill traces and is bounded by the declared bucket set
        (warm() compiles them all up front); `splice_traces` counts the
        prefix-cache row-copy and stays at 1 (splices run at full pool-row
        shape);
      * no head-of-line blocking — at most ONE prefill chunk runs between
        decode ticks, so an admission never stalls live decodes for more
        than one chunk of work (`max_decode_stall_ticks` <= 1);
      * per-request determinism — a request's token stream depends only on
        (prompt, seed, sampling params), never on which slot it landed in,
        what shared the batch, how its prompt was chunked, whether a
        prefix-cache splice skipped part of it, or which neighbours were
        cancelled mid-flight.
    """

    def __init__(self, rt, vocab: int, *, slots: int, max_context: int,
                 eos_id: Optional[int] = None, prefill_chunk: int = 32,
                 draft=None, spec_k: int = 0, prefix_cache=None, mesh=None):
        if slots < 1:
            raise ValueError("need at least one slot")
        if prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        if getattr(rt, "extras", None):
            raise NotImplementedError(
                "continuous batching over cross-attention runtimes (vlm/"
                "audio) needs per-request source encodings; the engine "
                "currently schedules self-attention and recurrent archs")
        if (draft is None) != (spec_k == 0) or spec_k < 0:
            raise ValueError("speculative mode needs BOTH a draft runtime "
                             "and spec_k >= 1 (got draft="
                             f"{'set' if draft is not None else 'None'}, "
                             f"spec_k={spec_k})")
        if spec_k > 64:
            # a verify may overshoot a slot's quota by up to spec_k cache
            # writes; attention pools carry DECODE_MARGIN (128) slack
            # columns past max_context, and staying well inside it keeps
            # the non-ring write clamp from ever aliasing a LIVE row
            raise ValueError(f"spec_k={spec_k} is past the supported draft "
                             "span (64); deep speculation gains nothing — "
                             "acceptance decays geometrically")
        if draft is not None:
            if not (getattr(rt, "spec_capable", False)
                    and getattr(draft, "spec_capable", False)):
                raise NotImplementedError(
                    "speculative decoding needs an exactly-rollbackable "
                    "multi-token step on both runtimes: RNN families and "
                    "pure-attention non-ring archs qualify; ring-cache, "
                    "MoE and rwkv/mamba runtimes do not (DESIGN.md §9)")
            if getattr(draft, "family", None) != getattr(rt, "family", None):
                raise ValueError("draft and target must be the same serving "
                                 "family — self-speculation pairs a packed "
                                 "export with its own fp masters")
        self.rt = rt
        self.vocab = int(vocab)
        self.n_slots = int(slots)
        self.max_context = int(max_context)
        self.eos_id = eos_id
        self.prefill_chunk = int(prefill_chunk)
        # how the runtime lets prompts be split (serve/recurrent.py):
        # 'token' granularity chunks anywhere; 'whole' archs (MoE capacity
        # competition, rwkv/mamba internal scan chunking) prefill the prompt
        # as one in-slot chunk.  pad_buckets = padded tails are exact.
        self._granularity = getattr(rt, "chunk_granularity", "whole")
        self._pad = bool(getattr(rt, "pad_buckets", False))

        # prefix-state caching (DESIGN.md §10): boundaries are exact
        # carried-state offsets only under token-granularity chunking, and
        # the narrowed attention snapshot assumes non-ring caches (a ring's
        # live window need not start at column 0)
        self.prefix_cache = prefix_cache
        if prefix_cache is not None:
            if not (self._granularity == "token"
                    and (getattr(rt, "family", None) == "rnn" or self._pad)):
                raise NotImplementedError(
                    "prefix-state caching needs token-granularity chunked "
                    "prefill and (for attention archs) non-ring caches — "
                    "the §8 bit-exact chunk-boundary contract is what makes "
                    "a spliced prefix byte-identical to re-prefilling it")
            prefix_cache.bind(self.prefill_chunk)

        self.pool = rt.init_state(self.n_slots, self.max_context,
                                  per_slot=True)
        # batch-1 template: fixes the slot axis of every pool leaf for the
        # gather/reset surgery (shapes only — no arrays are materialized)
        self._ref = jax.eval_shape(
            lambda: rt.init_state(1, self.max_context, per_slot=True))
        # the parameter trees every jitted region takes as ARGUMENTS (see
        # module docstring: closing over them constant-folds the weights)
        self._prm = rt.jit_prm
        # speculative mode (DESIGN.md §9): the packed draft runs its OWN
        # slot pool in lockstep with the target's — admission prefills
        # both, retirement scrubs both, and the spec tick rolls both back
        # to the accepted prefix
        self.draft = draft
        self.spec_k = int(spec_k)
        self.spec = draft is not None
        if self.spec:
            self.draft_pool = draft.init_state(self.n_slots,
                                               self.max_context,
                                               per_slot=True)
            self._dref = jax.eval_shape(
                lambda: draft.init_state(1, self.max_context, per_slot=True))
            self._dprm = draft.jit_prm
        B = self.n_slots
        self._pending = jnp.zeros((B,), jnp.int32)   # next token to feed
        self._live = jnp.zeros((B,), bool)
        self._keys = jnp.zeros((B, 2), jnp.uint32)   # per-slot PRNG chain
        self._temp = jnp.ones((B,), jnp.float32)
        self._topk = jnp.zeros((B,), jnp.int32)
        self._live_host = np.zeros(B, bool)
        self._active: List[Optional[_Active]] = [None] * B
        self._prefill_q: Deque[int] = deque()   # slots mid-prefill, FIFO
        self._rid = 0
        # the admission queue: a priority heap of submitted-but-unadmitted
        # requests ordered (priority, arrival_s, submit seq).  Cancellation
        # of a queued request is lazy: the rid goes into `_cancel_pending`
        # and the entry is dropped when it reaches the heap top.
        self._heap: List[Tuple[int, float, int, int, Request]] = []
        self._seq = 0
        self._queued_rids: Set[int] = set()
        self._cancel_pending: Set[int] = set()
        self._t0 = time.perf_counter()

        self.ticks = 0
        self.tick_traces = 0      # python counters bumped at TRACE time only
        self.prefill_traces = 0
        self.spec_traces = 0
        self.splice_traces = 0
        # Pallas launches the decode tick dispatches per call, measured the
        # same way tick_traces is: the kernel wrappers bump a trace-time
        # counter (kernels/dispatch.py) and the tick body diffs it while
        # being traced.  1 on the packed whole-tick path, 0 on the CPU
        # dense-fallback path (no interpret-mode Pallas in serving), -1
        # until the first tick traces.
        self.tick_launches = -1
        self._occupancy_sum = 0.0
        self._gen_tokens = 0      # cumulative over the engine's life
        self._drafted = 0         # speculative accounting: proposed drafts
        self._accepted = 0        # ... and how many of them survived verify
        # decode-stall accounting: chunks an admission ran since the last
        # decode tick while live decodes were waiting.  The scheduler's
        # contract is that this never exceeds ONE chunk per admission.
        self._stall_pending: Dict[int, int] = {}
        self._stall_max = 0

        # -- mesh placement (DESIGN.md §12) ---------------------------------
        # A mesh-native engine shards the slot pool over the mesh's data
        # axes (slot s lives on shard s // (slots/D)) and the weights
        # tensor-parallel over 'model' per the runtime's serving rules,
        # then pins every jitted region's in/out shardings so the layouts
        # are part of the ONE trace — admit/retire/splice between ticks
        # can never force a reshard, and tick_traces==1 holds per mesh
        # exactly as it does per device.
        self.mesh = mesh
        self._data_shards = 1
        self._pool_sh = self._dpool_sh = None
        self._sub_sh = self._dsub_sh = None
        self._prm_sh = self._dprm_sh = None
        self._vec_sh = self._row_sh = self._rep = None
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.kernels import dispatch
            from repro.launch.sharding import (batch_shardings,
                                               serve_pool_shardings)
            if dispatch.packed_pallas_active(
                    (self._prm, self._dprm if self.spec else None)):
                raise NotImplementedError(
                    "mesh-sharded serving of packed trees runs through the "
                    "compiled dense fallback (CPU) — the packed Pallas "
                    "kernels are single-device launches; their shard_map "
                    "port is the ROADMAP item")
            daxes = [a for a in ("pod", "data") if mesh.shape.get(a, 1) > 1]
            D = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1
            if self.n_slots % D:
                raise ValueError(
                    f"slots={self.n_slots} must split evenly over the "
                    f"mesh's {D} data shard(s) — the slot pool shards "
                    f"along 'data'")
            self._data_shards = D
            self._rep = NamedSharding(mesh, P())
            self._vec_sh, self._row_sh = batch_shardings(
                (self._pending, self._keys), mesh)
            self._pool_sh = serve_pool_shardings(self.pool, self._ref, mesh)
            self._sub_sh = jax.tree.map(lambda _: self._rep, self._pool_sh)
            self._prm_sh = rt.serve_prm_shardings(mesh)
            self.pool = jax.device_put(self.pool, self._pool_sh)
            self._prm = jax.device_put(self._prm, self._prm_sh)
            self._pending = jax.device_put(self._pending, self._vec_sh)
            self._live = jax.device_put(self._live, self._vec_sh)
            self._keys = jax.device_put(self._keys, self._row_sh)
            self._temp = jax.device_put(self._temp, self._vec_sh)
            self._topk = jax.device_put(self._topk, self._vec_sh)
            if self.spec:
                self._dpool_sh = serve_pool_shardings(
                    self.draft_pool, self._dref, mesh)
                self._dsub_sh = jax.tree.map(lambda _: self._rep,
                                             self._dpool_sh)
                self._dprm_sh = draft.serve_prm_shardings(mesh)
                self.draft_pool = jax.device_put(self.draft_pool,
                                                 self._dpool_sh)
                self._dprm = jax.device_put(self._dprm, self._dprm_sh)

        def _mjit(fn, in_sh=None, out_sh=None, donate=()):
            # sharding-annotated jit for the mesh-native engine; the
            # mesh=None engine compiles exactly as before.  Pinning BOTH
            # sides means host-built operands (chunk tokens, slot indices,
            # reset masks, fresh PRNG keys) are placed on entry and every
            # result lands already laid out for the next region.
            if mesh is None:
                return jax.jit(fn, donate_argnums=donate)
            return jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                           donate_argnums=donate)

        def tick(prm, pool, pending, live, keys, temp, topk):
            self.tick_traces += 1
            from repro.kernels import dispatch
            launches0 = dispatch.launch_count()
            logits, pool = rt.decode_fn(pending, pool, live, prm=prm)
            self.tick_launches = dispatch.launch_count() - launches0
            ks = jax.vmap(jax.random.split)(keys)    # (B, 2, 2)
            nxt = sample_slots(logits, ks[:, 1], temperature=temp,
                               top_k=topk, vocab=self.vocab)
            # dead slots: freeze the key chain and keep feeding the same
            # token, so a zombie slot's arrays are time-invariant
            nxt = jnp.where(live, nxt, pending)
            keys = jnp.where(live[:, None], ks[:, 0], keys)
            return pool, nxt, keys

        # the pool is dead the moment the tick/prefill/reset returns its
        # successor, so donate it (and the pending/key chains) — without
        # donation every tick would COPY all B KV caches.  CPU ignores
        # donation with a warning, so only ask off-CPU.  The prm tree is
        # NEVER donated: the same arrays are passed every call.
        cpu = jax.default_backend() == "cpu"
        self._tick = _mjit(
            tick,
            in_sh=(self._prm_sh, self._pool_sh, self._vec_sh, self._vec_sh,
                   self._row_sh, self._vec_sh, self._vec_sh),
            out_sh=(self._pool_sh, self._vec_sh, self._row_sh),
            donate=() if cpu else (1, 2, 4))

        def admit_commit(logits, key, t, k, pending, keys, temp, topk, live,
                         slot):
            # the request's first token: same key discipline as the
            # sequential loop (split once, sample with the second half) —
            # plus ALL the slot-array writes the admission needs, in ONE
            # dispatch (five eager at[].set calls used to dominate the
            # admission cost on CPU)
            ks = jax.random.split(key)
            tok = sample_slots(logits, ks[1][None], temperature=t[None],
                               top_k=k[None], vocab=self.vocab)[0]
            return (tok, pending.at[slot].set(tok), keys.at[slot].set(ks[0]),
                    temp.at[slot].set(t), topk.at[slot].set(k),
                    live.at[slot].set(True))

        R = self._rep
        self._admit_commit = _mjit(
            admit_commit,
            in_sh=(R, R, R, R, self._vec_sh, self._row_sh, self._vec_sh,
                   self._vec_sh, self._vec_sh, R),
            out_sh=(R, self._vec_sh, self._row_sh, self._vec_sh,
                    self._vec_sh, self._vec_sh))

        write = rt.write_slots if hasattr(rt, "write_slots") else tree_write_slot

        def prefill_slot(prm, pool, tokens, n, slot):
            # in-slot chunked prefill: the slot row IS the session state.
            # Retraces once per bucket length (tokens' static shape); slot
            # and n are traced, so one trace serves every admission.
            self.prefill_traces += 1
            sub = tree_gather_slot(pool, self._ref, slot)
            logits, sub = rt.prefill_chunk(tokens, sub, n, prm=prm)
            return logits, write(pool, sub, slot)

        self._prefill_slot = _mjit(
            prefill_slot,
            in_sh=(self._prm_sh, self._pool_sh, R, R, R),
            out_sh=(R, self._pool_sh),
            donate=() if cpu else (1,))
        # retire-time slot scrub, shape-aware: recurrent leaves + positions
        # to zero, attention KV masked in place, the device live bit
        # cleared — the freed row must read as fresh because the next
        # prefill resumes from it
        self._reset = _mjit(
            lambda pool, live, mask: (
                tree_reset_slots(pool, self._ref, mask),
                jnp.where(mask, False, live)),
            in_sh=(self._pool_sh, self._vec_sh, self._vec_sh),
            out_sh=(self._pool_sh, self._vec_sh),
            donate=() if cpu else (0,))

        if self.prefix_cache is not None:
            # prefix-cache device paths.  The splice is the SAME full-row
            # write admission prefill uses (entries are widened to the pool
            # row shape outside jit), so it traces exactly once; the gather
            # reads the slot row for snapshotting without donating the pool.
            self._gather = _mjit(
                lambda pool, slot: tree_gather_slot(pool, self._ref, slot),
                in_sh=(self._pool_sh, R), out_sh=self._sub_sh)

            def splice(pool, sub, slot):
                self.splice_traces += 1
                return write(pool, sub, slot)

            self._splice = _mjit(
                splice, in_sh=(self._pool_sh, self._sub_sh, R),
                out_sh=self._pool_sh, donate=() if cpu else (0,))

        if not self.spec:
            return

        # -- speculative mode: draft k, verify k+1, accept, commit ----------
        K = self.spec_k

        def spec_tick(prm, dprm, pool, dpool, pending, live, keys, temp,
                      topk):
            """One draft-verify-accept round over ALL live slots, jitted as
            a unit (traces exactly once — asserted like the plain tick):

              1. the packed draft proposes K tokens per slot: a scan of
                 K+1 batched draft decode steps (the last one advances the
                 draft through its own K-th proposal so a fully-accepted
                 round leaves the draft in sync), sampling proposals with
                 each slot's own temperature/top-k;
              2. the target verifies all candidates in ONE multi-token
                 step — `rt.verify` returns logits at every position;
              3. `spec_accept` runs the rejection rule per slot: the
                 output distribution is exactly the target's, and at
                 temperature 0 the emitted bytes are plain greedy decode;
              4. both pools COMMIT to each slot's accepted prefix:
                 per-step-state select for RNN families, KV suffix
                 restore + pos rewind for attention (the PR 3/4 slot
                 surgery, turned into a rollback primitive).

            Dead slots (empty or mid-prefill) stay bit-frozen: their
            decode rows are masked, their accepted count is forced to 0
            (commit restores their pre-round state exactly), and their
            pending/key chains never advance."""
            self.spec_traces += 1
            ks = jax.vmap(jax.random.split)(keys)          # (B, 2, 2)
            rk = ks[:, 1]
            new_keys = jnp.where(live[:, None], ks[:, 0], keys)
            dkeys = jax.vmap(
                lambda k: jax.random.split(jax.random.fold_in(k, 1),
                                           K + 1))(rk)     # (B, K+1, 2)
            akeys = jax.vmap(jax.random.fold_in,
                             in_axes=(0, None))(rk, 2)     # (B, 2)

            dsnap = draft.spec_snapshot(dpool, K + 1)

            def dbody(carry, step_keys):
                dst, tok = carry
                lg, dst = draft.decode_fn(tok, dst, live, prm=dprm)
                nxt = sample_slots(lg, step_keys, temperature=temp,
                                   top_k=topk, vocab=self.vocab)
                nxt = jnp.where(live, nxt, tok)
                return (dst, nxt), (lg, nxt, draft.spec_emit(dst))

            (dafter, _), (qlg, dtoks, demits) = jax.lax.scan(
                dbody, (dpool, pending), jnp.swapaxes(dkeys, 0, 1))
            drafts = jnp.swapaxes(dtoks[:K], 0, 1)         # (B, K)
            q_logits = jnp.swapaxes(qlg[:K], 0, 1)         # (B, K, V)

            vtokens = jnp.concatenate([pending[:, None], drafts], axis=1)
            vsnap = rt.spec_snapshot(pool, K + 1)
            p_logits, vafter, vemits = rt.verify(vtokens, pool, live,
                                                 prm=prm)

            n_acc, out = spec_accept(p_logits, q_logits, drafts, akeys,
                                     temperature=temp, top_k=topk,
                                     vocab=self.vocab)
            n_acc = jnp.where(live, n_acc, 0)
            pool = rt.spec_commit(pool, vafter, vsnap, vemits, n_acc)
            dpool = draft.spec_commit(dpool, dafter, dsnap, demits, n_acc)
            nxt_p = jnp.take_along_axis(
                out, jnp.maximum(n_acc - 1, 0)[:, None], axis=1)[:, 0]
            pending = jnp.where(live, nxt_p, pending)
            # ONE host-bound array per round: emitted tokens with the
            # accepted count in the last column (a second small transfer
            # costs as much as the whole verify at reduced scale)
            packed = jnp.concatenate([out, n_acc[:, None]], axis=1)
            return pool, dpool, pending, new_keys, packed

        self._spec_tick = _mjit(
            spec_tick,
            in_sh=(self._prm_sh, self._dprm_sh, self._pool_sh,
                   self._dpool_sh, self._vec_sh, self._vec_sh, self._row_sh,
                   self._vec_sh, self._vec_sh),
            out_sh=(self._pool_sh, self._dpool_sh, self._vec_sh,
                    self._row_sh, self._row_sh),
            donate=() if cpu else (2, 3, 4, 6))

        dwrite = (draft.write_slots if hasattr(draft, "write_slots")
                  else tree_write_slot)

        def spec_prefill_slot(prm, dprm, pool, dpool, tokens, n, slot):
            # same in-slot chunk as the plain path, run against BOTH pools
            # in one jitted region — the draft must carry the same prompt
            # state as the target or its proposals start from nowhere.
            # Trace-bounded by the same bucket set (one counter).
            self.prefill_traces += 1
            sub = tree_gather_slot(pool, self._ref, slot)
            logits, sub = rt.prefill_chunk(tokens, sub, n, prm=prm)
            dsub = tree_gather_slot(dpool, self._dref, slot)
            _, dsub = draft.prefill_chunk(tokens, dsub, n, prm=dprm)
            return (logits, write(pool, sub, slot),
                    dwrite(dpool, dsub, slot))

        self._spec_prefill_slot = _mjit(
            spec_prefill_slot,
            in_sh=(self._prm_sh, self._dprm_sh, self._pool_sh,
                   self._dpool_sh, R, R, R),
            out_sh=(R, self._pool_sh, self._dpool_sh),
            donate=() if cpu else (2, 3))
        self._spec_reset = _mjit(
            lambda pool, dpool, live, mask: (
                tree_reset_slots(pool, self._ref, mask),
                tree_reset_slots(dpool, self._dref, mask),
                jnp.where(mask, False, live)),
            in_sh=(self._pool_sh, self._dpool_sh, self._vec_sh,
                   self._vec_sh),
            out_sh=(self._pool_sh, self._dpool_sh, self._vec_sh),
            donate=() if cpu else (0, 1))

        if self.prefix_cache is not None:
            self._dgather = _mjit(
                lambda pool, slot: tree_gather_slot(pool, self._dref, slot),
                in_sh=(self._dpool_sh, R), out_sh=self._dsub_sh)

            def dsplice(dpool, dsub, slot):
                return dwrite(dpool, dsub, slot)

            self._dsplice = _mjit(
                dsplice, in_sh=(self._dpool_sh, self._dsub_sh, R),
                out_sh=self._dpool_sh, donate=() if cpu else (0,))

    # -- clock --------------------------------------------------------------

    def _now(self) -> float:
        return time.perf_counter() - self._t0

    # -- admission ----------------------------------------------------------

    def _validate(self, req: Request) -> None:
        size = int(np.asarray(req.prompt).size)
        if size == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if req.max_tokens < 1:
            raise ValueError(f"request {req.rid}: max_tokens must be >= 1 "
                             f"(got {req.max_tokens}) — the last prompt "
                             f"chunk always samples the first token")
        if size + req.max_tokens > self.max_context:
            raise ValueError(
                f"request {req.rid}: needs {size}+{req.max_tokens} tokens; "
                f"engine provisioned max_context={self.max_context}")

    def _chunk_plan(self, size: int) -> List[Tuple[int, int]]:
        """Split a prompt of `size` tokens into (bucket_len, n_real) chunks.
        'token' granularity: full `prefill_chunk` chunks plus a tail,
        bucket-padded to a power of two when the runtime supports exact
        padding.  'whole' granularity: the prompt is one chunk."""
        C = self.prefill_chunk
        if self._granularity == "whole":
            return [(size, size)]
        plan = [(C, C)] * (size // C)
        r = size % C
        if r:
            plan.append((_bucket(r, C), r) if self._pad else (r, r))
        return plan

    def declared_buckets(self, prompt_lens: Sequence[int] = ()) -> List[int]:
        """The static chunk lengths `warm()` compiles.  Bucket-padding
        runtimes declare the traffic-independent power-of-two set — after
        warming it, NO workload can trace a new prefill shape.  Exact-length
        runtimes derive the set from the prompt lengths they are told about
        (plus the full chunk)."""
        bs = {1}  # warm()'s throwaway request prefills a 1-token prompt
        lens = {int(l) for l in prompt_lens if int(l) > 0}
        if self._granularity == "whole":
            bs |= lens
        elif self._pad:
            C = self.prefill_chunk
            bs.add(C)
            b = 1
            while b < C:
                bs.add(b)
                b <<= 1
        else:
            for l in lens:
                bs |= {Lb for Lb, _ in self._chunk_plan(l)}
        return sorted(bs)

    def warm(self, prompt_lens: Sequence[int] = ()) -> None:
        """Compile outside the measured run: one prefill trace per declared
        chunk bucket, plus the tick and the first-token sampler.  After
        this, a measured `run()` performs ZERO new traces (asserted in
        tests via the prefill_traces/tick_traces counters).  Shared by the
        --traffic launcher and the benchmark so both measure the same
        warmed serving path."""
        for Lb in self.declared_buckets(prompt_lens):
            if self.spec:
                _, self.pool, self.draft_pool = self._spec_prefill_slot(
                    self._prm, self._dprm, self.pool, self.draft_pool,
                    jnp.zeros((1, Lb), jnp.int32), jnp.int32(Lb),
                    jnp.int32(0))
            else:
                _, self.pool = self._prefill_slot(
                    self._prm, self.pool, jnp.zeros((1, Lb), jnp.int32),
                    jnp.int32(Lb), jnp.int32(0))
        # the warm prefills ran junk through slot 0 — scrub it so the pool
        # is indistinguishable from fresh before any real admission.  (They
        # ran OUTSIDE _prefill_step, so no junk prefix was offered to the
        # prefix cache either.)
        mask = np.zeros(self.n_slots, bool)
        mask[0] = True
        self._scrub(mask)
        # a throwaway request exercises admit + sample + the tick and
        # leaves every slot idle again; max_tokens respects tiny contexts
        n = min(2, self.max_context - 1)
        if n >= 1:
            self.run([Request(prompt=np.zeros(1, np.int32), max_tokens=n,
                              temperature=1.0, top_k=0, seed=0, rid=-1)],
                     realtime=False)

    def _free_slot(self) -> Optional[int]:
        # a slot is busy while PREFILLING too (live only after its first
        # token), so occupancy is "has an _Active", not the decode mask
        busy = np.array([a is not None for a in self._active])
        idle = np.flatnonzero(~busy)
        if not idle.size:
            return None
        if self._data_shards <= 1:
            return int(idle[0])
        # mesh: spread admissions over the data shards (slot s lives on
        # shard s // per — contiguous blocks, see serve_pool_shardings)
        # so a half-empty pool decodes on D shards instead of piling onto
        # shard 0.  Slot choice never affects a request's bytes (the §7
        # per-request determinism invariant), so balancing is free.
        per = self.n_slots // self._data_shards
        occ = busy.reshape(self._data_shards, per).sum(axis=1)
        return int(min(idle, key=lambda s: (occ[s // per], int(s))))

    # -- the resumable scheduling API (DESIGN.md §10) -----------------------

    def submit(self, req: Request) -> int:
        """Enqueue one request for admission.  Returns its rid — the handle
        `cancel` and the step events refer to.  Safe to call between any
        two `step()` calls; the request is admitted (in priority order) as
        soon as a slot frees."""
        self._validate(req)
        rid = self._rid if req.rid is None else req.rid
        self._rid = max(self._rid, rid) + 1
        heapq.heappush(self._heap,
                       (req.priority, req.arrival_s, self._seq, rid, req))
        self._seq += 1
        self._queued_rids.add(rid)
        return rid

    def cancel(self, rid: int) -> Optional[Completion]:
        """Retire request `rid` wherever it is: queued (dropped before it
        ever touches a slot), mid-prefill, or mid-decode.  In-flight
        cancellation goes through the SAME batched shape-aware scrub as
        normal retirement — the freed slot reads exactly like a fresh one,
        so a hung-up client cannot leak state into the next occupant (and
        no new jit traces occur: the scrub is already compiled).

        Returns a Completion with finished='cancelled' carrying the tokens
        streamed so far, or None if the rid is unknown / already done."""
        for slot, act in enumerate(self._active):
            if act is not None and act.rid == rid:
                now = self._now()
                if slot in self._prefill_q:
                    self._prefill_q.remove(slot)
                    self._stall_pending.pop(rid, None)
                comp = self._completion(act, slot, now, finished="cancelled")
                self._retire(slot)
                mask = np.zeros(self.n_slots, bool)
                mask[slot] = True
                self._scrub(mask)
                return comp
        if rid in self._queued_rids:
            # lazy heap deletion: the entry is skipped when it surfaces
            self._queued_rids.discard(rid)
            self._cancel_pending.add(rid)
            req = next(r for (_, _, _, hr, r) in self._heap if hr == rid)
            now = self._now()
            return Completion(
                rid=rid, tokens=[],
                prompt_len=int(np.asarray(req.prompt).size),
                finished="cancelled", slot=-1, t_submit=req.arrival_s,
                t_admit=now, t_first=now, t_done=now, slo=req.slo)
        return None

    def has_work(self) -> bool:
        """True while a `step()` could make progress: queued, prefilling or
        decoding requests exist."""
        return bool(self._heap or self._prefill_q or self._live_host.any())

    def step(self) -> Tuple[List[Tuple[int, List[int]]], List[Completion]]:
        """ONE scheduler iteration: admit queued requests into free slots
        (priority order), run at most one prefill chunk, run one batched
        decode tick (or draft-verify-accept round), retire and scrub.

        Returns (token_events, completions): token_events is a list of
        (rid, [token ids sampled this iteration]) in stream order — one id
        per live slot per plain tick, up to spec_k+1 per spec round, the
        first token when a prompt's last chunk lands; completions are the
        requests that finished this iteration.  `run()` is a loop over
        this; the front door calls it from an event loop, interleaving
        `submit`/`cancel` between iterations."""
        now = self._now()
        while self._heap:
            slot = self._free_slot()
            if slot is None:
                break
            _, _, _, rid, req = heapq.heappop(self._heap)
            if rid in self._cancel_pending:
                self._cancel_pending.discard(rid)
                continue
            self._queued_rids.discard(rid)
            self._admit(req, rid, slot, self._now())

        retired = np.zeros(self.n_slots, bool)
        events: List[Tuple[int, List[int]]] = []
        comps: List[Completion] = []

        # at most ONE prefill chunk per iteration, before the tick
        if self._prefill_q:
            act0 = self._active[self._prefill_q[0]]
            if self._live_host.any():
                self._stall_pending[act0.rid] = \
                    self._stall_pending.get(act0.rid, 0) + 1
            sampled, comp, slot = self._prefill_step()
            if sampled:
                self._gen_tokens += sampled
                events.append((act0.rid, [act0.tokens[-1]]))
            if comp is not None:
                comps.append(comp)
                retired[slot] = True

        if not self._live_host.any():
            if retired.any():
                self._scrub(retired)
            return events, comps

        if self.spec:
            (self.pool, self.draft_pool, self._pending, self._keys,
             spec_out) = self._spec_tick(
                self._prm, self._dprm, self.pool, self.draft_pool,
                self._pending, self._live, self._keys, self._temp,
                self._topk)
        else:
            self.pool, self._pending, self._keys = self._tick(
                self._prm, self.pool, self._pending, self._live, self._keys,
                self._temp, self._topk)
        self.ticks += 1
        if self._stall_pending:
            self._stall_max = max(self._stall_max,
                                  max(self._stall_pending.values()))
            self._stall_pending.clear()
        n_live = int(self._live_host.sum())
        # a prefilling slot is BUSY (it cannot be admitted into), so
        # occupancy counts it — same "slot is taken" meaning as before
        # chunked prefill, when admission held the slot synchronously
        self._occupancy_sum += (n_live + len(self._prefill_q)) / self.n_slots

        # one small device->host transfer per tick: the scheduler needs
        # the sampled ids to detect EOS / quota and to free slots
        now = self._now()
        if self.spec:
            # a spec round emits a VARIABLE number of tokens per slot
            # (accepted prefix + one); truncate at EOS / quota — the
            # overshoot the verify consumed dies with the slot scrub
            out_host = np.asarray(spec_out)
            for slot in np.flatnonzero(self._live_host):
                act = self._active[slot]
                take = int(out_host[slot, -1])
                self._drafted += self.spec_k
                self._accepted += max(take - 1, 0)
                emitted: List[int] = []
                done = False
                for j in range(take):
                    tok = int(out_host[slot, j])
                    act.tokens.append(tok)
                    emitted.append(tok)
                    self._gen_tokens += 1
                    hit_eos = (self.eos_id is not None
                               and act.tokens[-1] == self.eos_id)
                    if hit_eos or len(act.tokens) >= act.req.max_tokens:
                        done = True
                        break
                events.append((act.rid, emitted))
                if done:
                    comps.append(self._completion(act, int(slot), now))
                    self._retire(int(slot))
                    retired[slot] = True
        else:
            self._gen_tokens += n_live
            toks = np.asarray(self._pending)
            for slot in np.flatnonzero(self._live_host):
                act = self._active[slot]
                act.tokens.append(int(toks[slot]))
                events.append((act.rid, [int(toks[slot])]))
                hit_eos = (self.eos_id is not None
                           and act.tokens[-1] == self.eos_id)
                if hit_eos or len(act.tokens) >= act.req.max_tokens:
                    comps.append(self._completion(act, int(slot), now))
                    self._retire(int(slot))
                    retired[slot] = True
        if retired.any():
            # scrub the freed slots in ONE batched shape-aware reset:
            # the next occupant prefills IN the slot, so it must read
            # exactly like a fresh one
            self._scrub(retired)
        return events, comps

    # -- internals ----------------------------------------------------------

    def _admit(self, req: Request, rid: int, slot: int, now: float) -> None:
        """Pure bookkeeping plus (with a prefix cache) at most one splice:
        look up the longest cached prompt prefix, copy its carried state
        into the slot row, split the REMAINING prompt into bucket-padded
        chunks and queue the slot for in-slot prefill.  The cached prefix
        is capped at size-1: the last chunk must still run because it
        samples the request's first token."""
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        cached = 0
        if self.prefix_cache is not None:
            cached, entry = self.prefix_cache.lookup(prompt)
            if entry is not None and self.spec and entry.draft_state is None:
                cached, entry = 0, None  # stored by a non-spec engine: no
            if entry is not None:        # draft half to keep in lockstep
                self._splice_entry(entry, slot)
        chunks: Deque[Tuple[np.ndarray, int]] = deque()
        off = cached
        for Lb, n in self._chunk_plan(prompt.size - cached):
            c = np.zeros(Lb, np.int32)
            c[:n] = prompt[off:off + n]
            off += n
            chunks.append((c, n))
        self._active[slot] = _Active(
            req=req, rid=rid, tokens=[], t_submit=req.arrival_s,
            t_admit=now, t_first=None, chunks=chunks, prompt=prompt,
            off=cached, cached=cached)
        self._prefill_q.append(slot)

    def _splice_entry(self, entry, slot: int) -> None:
        """Copy a cached prefix state into the slot row: widen narrowed
        attention leaves back to pool capacity (zero tail — masked exactly
        like the stale bytes retirement leaves), then ONE full-row write —
        for the RNN family that is the two (L, H) row copies
        `rnn_write_slots` was built from."""
        from repro.serve.prefixcache import widen_state

        sub = widen_state(entry.state, self._ref)
        self.pool = self._splice(self.pool, sub, jnp.int32(slot))
        if self.spec:
            dsub = widen_state(entry.draft_state, self._dref)
            self.draft_pool = self._dsplice(self.draft_pool, dsub,
                                            jnp.int32(slot))

    def _offer_snapshot(self, slot: int, act: _Active) -> None:
        """Offer the slot's carried state to the prefix cache at the
        chunk-boundary offset it just reached (skipped when the boundary is
        already cached — the digest check costs nothing device-side)."""
        from repro.serve.prefixcache import narrow_state

        prefix = act.prompt[:act.off]
        if self.prefix_cache.contains(prefix):
            return
        sub = narrow_state(self._gather(self.pool, jnp.int32(slot)), act.off)
        dsub = None
        if self.spec:
            dsub = narrow_state(
                self._dgather(self.draft_pool, jnp.int32(slot)), act.off)
        self.prefix_cache.insert(prefix, sub, dsub)

    def _prefill_step(self):
        """Run ONE chunk of the oldest prefilling slot.  When the last
        chunk lands, sample the request's first token (stamping the real
        `t_first`) and either turn the slot live or — max_tokens == 1 /
        EOS on the first token — complete it immediately.  Returns
        (n_sampled, completion, retired_slot)."""
        slot = self._prefill_q[0]
        act = self._active[slot]
        chunk, n = act.chunks.popleft()
        if self.spec:
            logits, self.pool, self.draft_pool = self._spec_prefill_slot(
                self._prm, self._dprm, self.pool, self.draft_pool,
                jnp.asarray(chunk)[None], jnp.int32(n), jnp.int32(slot))
        else:
            logits, self.pool = self._prefill_slot(
                self._prm, self.pool, jnp.asarray(chunk)[None], jnp.int32(n),
                jnp.int32(slot))
        act.off += n
        if (self.prefix_cache is not None and n == self.prefill_chunk
                and act.off % self.prefill_chunk == 0):
            self._offer_snapshot(slot, act)
        if act.chunks:
            return 0, None, None
        self._prefill_q.popleft()
        req = act.req
        (tok0, self._pending, self._keys, self._temp, self._topk,
         self._live) = self._admit_commit(
            logits, jax.random.PRNGKey(req.seed),
            jnp.float32(req.temperature), jnp.int32(req.top_k),
            self._pending, self._keys, self._temp, self._topk, self._live,
            jnp.int32(slot))
        act.tokens.append(int(tok0))
        act.t_first = self._now()
        if (req.max_tokens <= 1
                or (self.eos_id is not None and act.tokens[0] == self.eos_id)):
            # completed at admission: the device-side live bit was set by
            # the fused commit, and the caller's retired-mask scrub clears
            # it this same scheduler iteration (_live_host stays False, so
            # no host path ever reads the slot as live)
            comp = self._completion(act, slot, act.t_first)
            self._active[slot] = None
            return 1, comp, slot
        self._live_host[slot] = True
        return 1, None, None

    def _completion(self, act: _Active, slot: int, now: float,
                    finished: Optional[str] = None) -> Completion:
        if finished is None:
            hit_eos = (self.eos_id is not None and act.tokens
                       and act.tokens[-1] == self.eos_id)
            finished = "eos" if hit_eos else "length"
        return Completion(
            rid=act.rid, tokens=act.tokens,
            prompt_len=int(act.prompt.size),
            finished=finished, slot=slot,
            t_submit=act.t_submit, t_admit=act.t_admit,
            t_first=act.t_first if act.t_first is not None else act.t_admit,
            t_done=now, cached_tokens=act.cached, slo=act.req.slo)

    def _retire(self, slot: int) -> None:
        # host bookkeeping only: the device-side live bit clears in the
        # iteration's batched _scrub (one jitted call for all retirements)
        self._active[slot] = None
        self._live_host[slot] = False

    def _scrub(self, retired: np.ndarray) -> None:
        """Batched shape-aware reset of the freed slots — state rows, the
        device live mask, and the draft pool's matching rows in speculative
        mode (the next occupant prefills into BOTH pools)."""
        m = jnp.asarray(retired)
        if self.spec:
            self.pool, self.draft_pool, self._live = self._spec_reset(
                self.pool, self.draft_pool, self._live, m)
        else:
            self.pool, self._live = self._reset(self.pool, self._live, m)

    # -- stats (the front door's /v1/stats) ---------------------------------

    def stats(self) -> dict:
        """Cumulative engine-lifetime counters — what a serving fleet
        scrapes.  The trace counters ARE the compile-once invariants."""
        d = {
            "slots": self.n_slots,
            "active": sum(a is not None for a in self._active),
            "queued": len(self._queued_rids),
            "ticks": self.ticks,
            "gen_tokens": self._gen_tokens,
            "tick_traces": self.tick_traces,
            "tick_launches": self.tick_launches,
            "prefill_traces": self.prefill_traces,
            "max_decode_stall_ticks": self._stall_max,
        }
        # per-shard occupancy (queue depth is global — admission is one
        # priority heap feeding every shard): a router in front of a mesh
        # fleet reads this to spot an unbalanced mesh.  A mesh=None engine
        # is one shard, so the schema is unconditional.
        per = self.n_slots // self._data_shards
        busy = [a is not None for a in self._active]
        d["queue_depth"] = d["queued"]
        d["shards"] = [
            {"shard": i, "slots": per,
             "active": int(sum(busy[i * per:(i + 1) * per])),
             "occupancy": sum(busy[i * per:(i + 1) * per]) / per}
            for i in range(self._data_shards)]
        if self.mesh is not None:
            d["mesh"] = {str(a): int(n) for a, n in self.mesh.shape.items()}
        if self.spec:
            d.update({"spec_traces": self.spec_traces,
                      "drafted_tokens": self._drafted,
                      "accepted_drafts": self._accepted})
        if self.prefix_cache is not None:
            d["splice_traces"] = self.splice_traces
            d["prefix_cache"] = self.prefix_cache.stats()
        return d

    def tick_hlo(self) -> str:
        """Compiled HLO of the decode tick over the engine's CURRENT
        operands — the mesh tests grep it with `dispatch.collective_ops`
        to prove the data-sharded tick is communication-free.  Lowering
        re-runs the trace outside the serving path, so the trace/launch
        counters are saved and restored: tick_traces stays a property of
        the SERVING path, not of diagnostics."""
        t, l = self.tick_traces, self.tick_launches
        s = self.spec_traces
        try:
            if self.spec:
                low = self._spec_tick.lower(
                    self._prm, self._dprm, self.pool, self.draft_pool,
                    self._pending, self._live, self._keys, self._temp,
                    self._topk)
            else:
                low = self._tick.lower(
                    self._prm, self.pool, self._pending, self._live,
                    self._keys, self._temp, self._topk)
            return low.compile().as_text()
        finally:
            self.tick_traces, self.tick_launches = t, l
            self.spec_traces = s

    # -- the batch driver ---------------------------------------------------

    def run(self, requests: Sequence[Request], *, realtime: bool = True):
        """Drive a workload to completion.  Returns (completions, metrics).

        `realtime=True` honours `arrival_s` against the wall clock (traffic
        replay: a request is invisible until it arrives).  `realtime=False`
        treats arrivals as an admission-priority order only — fastest way
        to drain a batch, and what the deterministic parity tests use.

        A thin loop over `submit()` + `step()`: the batch driver and the
        front door run the IDENTICAL scheduler, so everything the fuzz
        harness proves about run() holds for the streaming path too."""
        for r in requests:  # fail fast, BEFORE any request is in flight:
            self._validate(r)  # a bad request must not poison the workload
        arrivals = deque(sorted(requests, key=lambda r: r.arrival_s))
        completions: List[Completion] = []
        self._t0 = time.perf_counter()
        gen0 = self._gen_tokens
        ticks0, occ0 = self.ticks, self._occupancy_sum  # per-run deltas
        drafted0, accepted0 = self._drafted, self._accepted
        self._stall_pending.clear()
        self._stall_max = 0

        while (arrivals or self._heap or self._prefill_q
               or self._live_host.any()):
            now = self._now()
            # release traffic that has arrived into the admission heap
            while arrivals and (not realtime or arrivals[0].arrival_s <= now):
                self.submit(arrivals.popleft())
            _, comps = self.step()
            completions.extend(comps)
            if (not self._prefill_q and not self._live_host.any()
                    and not self._heap and arrivals and realtime):
                # idle until the next arrival
                wait = arrivals[0].arrival_s - self._now()
                if wait > 0:
                    time.sleep(min(wait, 0.05))

        if self._stall_pending:  # prefill work after the last decode tick
            self._stall_max = max(self._stall_max,
                                  max(self._stall_pending.values()))
            self._stall_pending.clear()

        wall = time.perf_counter() - self._t0
        gen_tokens = self._gen_tokens - gen0
        ticks = self.ticks - ticks0
        occ = self._occupancy_sum - occ0
        lat = sorted(c.latency_s for c in completions)
        ttft = sorted(c.ttft_s for c in completions)
        pct = lambda xs, p: (xs[min(len(xs) - 1, int(p * len(xs)))]
                             if xs else 0.0)
        by_cls: Dict[str, List[float]] = {}
        for c in completions:
            by_cls.setdefault(c.slo, []).append(c.ttft_s)
        metrics = {
            "requests": len(completions),
            "wall_s": wall,
            "gen_tokens": gen_tokens,
            "agg_tok_s": gen_tokens / wall if wall > 0 else 0.0,
            "p50_latency_s": pct(lat, 0.50),
            "p95_latency_s": pct(lat, 0.95),
            "ttft_p50_s": pct(ttft, 0.50),
            "ttft_p95_s": pct(ttft, 0.95),
            "ttft_by_class": {
                cls: {"n": len(v), "p50_s": pct(sorted(v), 0.50),
                      "p95_s": pct(sorted(v), 0.95)}
                for cls, v in sorted(by_cls.items())},
            "max_decode_stall_ticks": self._stall_max,
            "ticks": ticks,
            "tick_traces": self.tick_traces,  # cumulative on purpose: the
            "prefill_traces": self.prefill_traces,  # invariants are ==1 and
            "occupancy": occ / ticks if ticks else 0.0,  # <= bucket count
        }
        if self.spec:
            drafted = self._drafted - drafted0
            accepted = self._accepted - accepted0
            metrics.update({
                "spec_k": self.spec_k,
                "spec_rounds": ticks,      # every tick is a spec round
                "spec_traces": self.spec_traces,  # cumulative: invariant ==1
                "drafted_tokens": drafted,
                "accepted_drafts": accepted,
                "accept_rate": accepted / drafted if drafted else 0.0,
                # drafted/s measures the packed proposer's raw speed; the
                # headline agg_tok_s is emitted (target-quality) tokens/s
                "draft_tok_s": drafted / wall if wall > 0 else 0.0,
            })
        if self.prefix_cache is not None:
            metrics["splice_traces"] = self.splice_traces
            metrics["prefix_cache"] = self.prefix_cache.stats()
        return completions, metrics
