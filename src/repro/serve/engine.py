"""Continuous-batching serve engine (DESIGN.md §7).

`ServeEngine` owns a fixed pool of B slots over any serving runtime
(BN-LSTM/GRU, RWKV6, Mamba2-hybrid, attention archs) and turns the lockstep
prefill→decode loop into mixed-length traffic serving:

  * requests are ADMITTED from a queue as slots free up: the new request is
    prefilled alone (batch 1, pool-shaped state) and spliced into its slot —
    for the RNN family that is two (L, H) row copies (the O(1) recurrent
    state is exactly what makes admission trivial), for attention archs a
    per-slot KV-row insert plus a per-slot position reset;
  * every tick runs ONE batched `decode_step` across all B slots with dead
    slots MASKED, never resliced — the tick's operand shapes are
    occupancy-independent, so jit traces the decode path exactly once and
    admit/retire between ticks cannot retrace it (asserted in tests);
  * slots RETIRE on EOS or per-request max-tokens and are immediately
    reusable; freed slots are scrubbed in one batched reset per tick
    (`rnn_reset_slots` zeroes h/c, `cache_reset_slots` drops the per-slot
    cache pos so stale KV reads as unwritten).

Sampling is per-slot vectorized (serve/sampler.sample_slots): each slot
carries its own temperature / top-k / PRNG key chain, and a slot's draws are
bit-identical to running that request alone through `drive_session` — the
engine changes the schedule, not the tokens.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.sampler import sample_slots

Array = jax.Array


@dataclasses.dataclass
class Request:
    """One generation request.  `arrival_s` is the submit time relative to
    engine start (0 = already queued) — the traffic replay sets it from a
    Poisson process; latency is measured against it."""

    prompt: Any                  # (S,) int token ids (list / np / jnp)
    max_tokens: int
    temperature: float = 0.8
    top_k: int = 0
    seed: int = 0
    arrival_s: float = 0.0
    rid: Optional[int] = None    # engine numbers admissions when None (the
                                 # Request object itself is never mutated)


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: List[int]            # sampled ids, EOS included when hit
    prompt_len: int
    finished: str                # 'length' | 'eos'
    slot: int
    t_submit: float              # engine-relative seconds
    t_admit: float
    t_first: float               # first token sampled (== admit: prefill samples)
    t_done: float

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit

    @property
    def queue_s(self) -> float:
        return self.t_admit - self.t_submit


@dataclasses.dataclass
class _Active:
    req: Request
    rid: int            # kept here so the caller's Request is never mutated
    tokens: List[int]
    t_submit: float
    t_admit: float


# ---------------------------------------------------------------------------
# generic slot surgery over state pytrees
# ---------------------------------------------------------------------------


def tree_write_slot(pool, sub, slot):
    """Insert a batch-1 state pytree into row `slot` of every pool leaf.

    Works for any state the runtimes produce — stacked or tail
    AttnCache/SSMState/RWKVState nodes and bare array leaves alike — by
    delegating AttnCache nodes to `kvcache.cache_write_slot` (the one
    attention-cache insert implementation) and everything else to
    `kvcache.write_row`, which recovers the slot axis per leaf from the
    static shapes.  `slot` itself is traced, so one compilation serves
    every admission."""
    from repro.serve.kvcache import AttnCache, cache_write_slot, write_row

    is_cache = lambda x: isinstance(x, AttnCache)
    return jax.tree.map(
        lambda p, s: (cache_write_slot(p, s, slot) if is_cache(p)
                      else write_row(p, s, slot)),
        pool, sub, is_leaf=is_cache)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


class ServeEngine:
    """Slotted continuous-batching scheduler over one serving runtime.

    eng = ServeEngine(rt, vocab, slots=8, max_context=512)
    completions, metrics = eng.run(requests)

    Invariants (DESIGN.md §7):
      * mask-don't-reshape — the pool state, the token/key/temperature
        arrays and therefore the jitted tick keep shape (B, ...) forever;
        occupancy lives in a boolean mask;
      * one trace — `tick_traces` counts jit traces of the decode tick and
        stays at 1 across arbitrary admit/retire interleavings;
      * per-request determinism — a request's token stream depends only on
        (prompt, seed, sampling params), never on which slot it landed in
        or what shared the batch.
    """

    def __init__(self, rt, vocab: int, *, slots: int, max_context: int,
                 eos_id: Optional[int] = None):
        if slots < 1:
            raise ValueError("need at least one slot")
        if getattr(rt, "extras", None):
            raise NotImplementedError(
                "continuous batching over cross-attention runtimes (vlm/"
                "audio) needs per-request source encodings; the engine "
                "currently schedules self-attention and recurrent archs")
        self.rt = rt
        self.vocab = int(vocab)
        self.n_slots = int(slots)
        self.max_context = int(max_context)
        self.eos_id = eos_id

        self.pool = rt.init_state(self.n_slots, self.max_context,
                                  per_slot=True)
        B = self.n_slots
        self._pending = jnp.zeros((B,), jnp.int32)   # next token to feed
        self._live = jnp.zeros((B,), bool)
        self._keys = jnp.zeros((B, 2), jnp.uint32)   # per-slot PRNG chain
        self._temp = jnp.ones((B,), jnp.float32)
        self._topk = jnp.zeros((B,), jnp.int32)
        self._live_host = np.zeros(B, bool)
        self._active: List[Optional[_Active]] = [None] * B
        self._rid = 0

        self.ticks = 0
        self.tick_traces = 0      # python counter bumped at TRACE time only
        self._occupancy_sum = 0.0

        def tick(pool, pending, live, keys, temp, topk):
            self.tick_traces += 1
            logits, pool = rt.decode_fn(pending, pool, live)
            ks = jax.vmap(jax.random.split)(keys)    # (B, 2, 2)
            nxt = sample_slots(logits, ks[:, 1], temperature=temp,
                               top_k=topk, vocab=self.vocab)
            # dead slots: freeze the key chain and keep feeding the same
            # token, so a zombie slot's arrays are time-invariant
            nxt = jnp.where(live, nxt, pending)
            keys = jnp.where(live[:, None], ks[:, 0], keys)
            return pool, nxt, keys

        # the pool is dead the moment the tick/write/reset returns its
        # successor, so donate it (and the pending/key chains) — without
        # donation every tick would COPY all B KV caches.  CPU ignores
        # donation with a warning, so only ask off-CPU.
        cpu = jax.default_backend() == "cpu"
        self._tick = jax.jit(tick, donate_argnums=() if cpu else (0, 1, 3))

        def admit_sample(logits, key, temp, topk):
            # the request's first token: same key discipline as the
            # sequential loop (split once, sample with the second half)
            ks = jax.random.split(key)
            tok = sample_slots(logits, ks[1][None], temperature=temp[None],
                               top_k=topk[None], vocab=self.vocab)[0]
            return tok, ks[0]

        self._admit_sample = jax.jit(admit_sample)
        write = rt.write_slots if hasattr(rt, "write_slots") else tree_write_slot
        self._write = jax.jit(write, donate_argnums=() if cpu else (0,))
        # retire-time slot scrub: RNN pools zero the slot's h/c
        # (bnlstm.rnn_reset_slots); attention pools drop the slot's per-slot
        # cache pos so stale KV is masked (kvcache.cache_reset_slots)
        self._reset = (jax.jit(rt.reset_slots,
                               donate_argnums=() if cpu else (0,))
                       if hasattr(rt, "reset_slots") else None)

    # -- admission ----------------------------------------------------------

    def _validate(self, req: Request) -> None:
        size = int(np.asarray(req.prompt).size)
        if size == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if req.max_tokens < 1:
            raise ValueError(f"request {req.rid}: max_tokens must be >= 1 "
                             f"(got {req.max_tokens}) — admission always "
                             f"samples the first token from the prefill")
        if size + req.max_tokens > self.max_context:
            raise ValueError(
                f"request {req.rid}: needs {size}+{req.max_tokens} tokens; "
                f"engine provisioned max_context={self.max_context}")

    def warm(self, prompt_lens: Sequence[int] = ()) -> None:
        """Compile outside the measured run: the tick plus one prefill per
        distinct prompt length (prefill traces per length; the tick never
        retraces).  Shared by the --traffic launcher and the benchmark so
        both measure the same warmed serving path."""
        for L in sorted({int(l) for l in prompt_lens if l > 0}):
            st = self.rt.init_state(1, self.max_context, per_slot=True)
            jax.block_until_ready(
                self.rt.prefill(jnp.zeros((1, L), jnp.int32), st)[0])
        # a throwaway request exercises admit + the tick and leaves every
        # slot idle again; max_tokens respects tiny max_context settings
        n = min(2, self.max_context - 1)
        if n >= 1:
            self.run([Request(prompt=np.zeros(1, np.int32), max_tokens=n,
                              temperature=1.0, top_k=0, seed=0, rid=-1)],
                     realtime=False)

    def _free_slot(self) -> Optional[int]:
        idle = np.flatnonzero(~self._live_host)
        return int(idle[0]) if idle.size else None

    def _admit(self, req: Request, slot: int, now: float) -> Optional[Completion]:
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        rid = self._rid if req.rid is None else req.rid
        self._rid = max(self._rid, rid) + 1

        sub = self.rt.init_state(1, self.max_context, per_slot=True)
        logits, sub = self.rt.prefill(jnp.asarray(prompt)[None], sub)
        tok0, key = self._admit_sample(
            logits, jax.random.PRNGKey(req.seed),
            jnp.float32(req.temperature), jnp.int32(req.top_k))
        self.pool = self._write(self.pool, sub, slot)
        self._pending = self._pending.at[slot].set(tok0)
        self._keys = self._keys.at[slot].set(key)
        self._temp = self._temp.at[slot].set(req.temperature)
        self._topk = self._topk.at[slot].set(req.top_k)

        act = _Active(req=req, rid=rid, tokens=[int(tok0)],
                      t_submit=req.arrival_s, t_admit=now)
        done = (req.max_tokens <= 1
                or (self.eos_id is not None and act.tokens[0] == self.eos_id))
        if done:
            return self._completion(act, slot, now)
        self._active[slot] = act
        self._live_host[slot] = True
        self._live = self._live.at[slot].set(True)
        return None

    def _completion(self, act: _Active, slot: int, now: float) -> Completion:
        hit_eos = (self.eos_id is not None and act.tokens
                   and act.tokens[-1] == self.eos_id)
        return Completion(
            rid=act.rid, tokens=act.tokens,
            prompt_len=int(np.asarray(act.req.prompt).size),
            finished="eos" if hit_eos else "length", slot=slot,
            t_submit=act.t_submit, t_admit=act.t_admit,
            t_first=act.t_admit, t_done=now)

    def _retire(self, slot: int) -> None:
        self._active[slot] = None
        self._live_host[slot] = False
        self._live = self._live.at[slot].set(False)

    # -- the run loop -------------------------------------------------------

    def run(self, requests: Sequence[Request], *, realtime: bool = True):
        """Drive a workload to completion.  Returns (completions, metrics).

        `realtime=True` honours `arrival_s` against the wall clock (traffic
        replay: a request is invisible until it arrives).  `realtime=False`
        treats arrivals as a priority order only — fastest way to drain a
        batch, and what the deterministic parity tests use."""
        for r in requests:  # fail fast, BEFORE any request is in flight:
            self._validate(r)  # a bad request must not poison the workload
        queue = deque(sorted(requests, key=lambda r: r.arrival_s))
        completions: List[Completion] = []
        t0 = time.perf_counter()
        gen_tokens = 0
        ticks0, occ0 = self.ticks, self._occupancy_sum  # per-run deltas

        while queue or self._live_host.any():
            now = time.perf_counter() - t0
            # admit while there is traffic that has arrived and a free slot
            while queue and (not realtime or queue[0].arrival_s <= now):
                slot = self._free_slot()
                if slot is None:
                    break
                req = queue.popleft()
                now = time.perf_counter() - t0
                done = self._admit(req, slot, now)
                gen_tokens += 1  # prefill samples the request's first token
                if done is not None:
                    completions.append(done)

            if not self._live_host.any():
                if queue and realtime:
                    # idle until the next arrival
                    wait = queue[0].arrival_s - (time.perf_counter() - t0)
                    if wait > 0:
                        time.sleep(min(wait, 0.05))
                continue

            self.pool, self._pending, self._keys = self._tick(
                self.pool, self._pending, self._live, self._keys,
                self._temp, self._topk)
            self.ticks += 1
            n_live = int(self._live_host.sum())
            self._occupancy_sum += n_live / self.n_slots
            gen_tokens += n_live

            # one small device->host transfer per tick: the scheduler needs
            # the sampled ids to detect EOS / quota and to free slots
            toks = np.asarray(self._pending)
            now = time.perf_counter() - t0
            retired = np.zeros(self.n_slots, bool)
            for slot in np.flatnonzero(self._live_host):
                act = self._active[slot]
                act.tokens.append(int(toks[slot]))
                hit_eos = (self.eos_id is not None
                           and act.tokens[-1] == self.eos_id)
                if hit_eos or len(act.tokens) >= act.req.max_tokens:
                    completions.append(self._completion(act, int(slot), now))
                    self._retire(int(slot))
                    retired[slot] = True
            if retired.any() and self._reset is not None:
                # scrub the freed slots in ONE batched call (rnn_reset_slots
                # / cache_reset_slots): zombie rows carry no stale state
                self.pool = self._reset(self.pool, jnp.asarray(retired))

        wall = time.perf_counter() - t0
        ticks = self.ticks - ticks0
        occ = self._occupancy_sum - occ0
        lat = sorted(c.latency_s for c in completions)
        pct = lambda p: lat[min(len(lat) - 1, int(p * len(lat)))] if lat else 0.0
        metrics = {
            "requests": len(completions),
            "wall_s": wall,
            "gen_tokens": gen_tokens,
            "agg_tok_s": gen_tokens / wall if wall > 0 else 0.0,
            "p50_latency_s": pct(0.50),
            "p95_latency_s": pct(0.95),
            "ticks": ticks,
            "tick_traces": self.tick_traces,  # cumulative on purpose: the
            "occupancy": occ / ticks if ticks else 0.0,  # invariant is ==1
        }
        return completions, metrics
