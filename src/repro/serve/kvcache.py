"""KV caches for serving: full, ring-buffer (sliding window), and cross-attn.

Sharding policy (decode-time memory dominates at 32k/500k):
  * batch axis -> ('pod', 'data')
  * KV heads   -> 'model' when divisible (GQA archs with >= mesh kv heads)
  * otherwise the SEQUENCE axis -> 'model' (length-sharded cache; attention
    over a length-sharded cache costs one small logits all-gather per step,
    but divides the dominant cache bytes by the TP degree).
This fallback is what makes e.g. llama3-8b decode_32k (8 kv heads, 16-way
model axis) fit: 4.3 GB/seq of cache is length-sharded instead of replicated.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.runtime import current_mesh

Array = jax.Array


@jax.tree_util.register_pytree_node_class
class AttnCache:
    """k, v: (B, C, Hkv, hd) with capacity C; pos: () int32 tokens written.
    `ring` (sliding-window buffer) is static pytree aux data, so it stays a
    python bool under jit/scan."""

    def __init__(self, k: Array, v: Array, pos: Array, ring: bool = False):
        self.k, self.v, self.pos, self.ring = k, v, pos, ring

    def _replace(self, **kw) -> "AttnCache":
        d = {"k": self.k, "v": self.v, "pos": self.pos, "ring": self.ring}
        d.update(kw)
        return AttnCache(**d)

    def tree_flatten(self):
        return (self.k, self.v, self.pos), self.ring

    @classmethod
    def tree_unflatten(cls, ring, children):
        return cls(*children, ring=ring)


class CrossCache(NamedTuple):
    k: Array      # (B, S_src, Hkv, hd) — fixed after prefill
    v: Array


def kv_pspec(batch: int, cap: int, heads: int) -> P:
    """Pick the cache PartitionSpec per the policy above."""
    mesh = current_mesh()
    if mesh is None:
        return P()
    bd = tuple(a for a in ("pod", "data") if a in mesh.shape)
    m = mesh.shape.get("model", 1)
    bspec = None
    prod = 1
    keep = []
    for a in bd:
        if batch % (prod * mesh.shape[a]) == 0:
            keep.append(a)
            prod *= mesh.shape[a]
    bspec = tuple(keep) if keep else None
    if m > 1 and heads % m == 0:
        return P(bspec, None, "model", None)
    if m > 1 and cap % m == 0:
        return P(bspec, "model", None, None)
    return P(bspec, None, None, None)


def constrain_cache(c: AttnCache) -> AttnCache:
    mesh = current_mesh()
    if mesh is None:
        return c
    spec = kv_pspec(c.k.shape[0], c.k.shape[1], c.k.shape[2])
    return c._replace(k=jax.lax.with_sharding_constraint(c.k, spec),
                      v=jax.lax.with_sharding_constraint(c.v, spec))


def cache_init(batch: int, cap: int, heads: int, hd: int, dtype,
               *, ring: bool = False) -> AttnCache:
    return AttnCache(
        k=jnp.zeros((batch, cap, heads, hd), dtype),
        v=jnp.zeros((batch, cap, heads, hd), dtype),
        pos=jnp.zeros((), jnp.int32),
        ring=ring,
    )


def cache_positions(c: AttnCache) -> Array:
    """Absolute position stored in each slot; -1 marks unwritten/invalid."""
    cap = c.k.shape[1]
    slots = jnp.arange(cap, dtype=jnp.int32)
    if c.ring:
        # slot s holds the largest a < pos with a % cap == s
        a = c.pos - 1 - jnp.mod(c.pos - 1 - slots, cap)
        return jnp.where((a >= 0) & (c.pos > 0), a, -1)
    return jnp.where(slots < c.pos, slots, -1)


def cache_update(c: AttnCache, k_new: Array, v_new: Array) -> AttnCache:
    """Append S_new tokens (prefill: S_new = S; decode: S_new = 1).

    Non-ring: writes at [pos, pos+S).  Ring: writes each token at its
    (absolute position % window) slot; assumes S_new <= capacity or the
    early tokens are overwritten (correct: they'd be out of window anyway).
    """
    cap = c.k.shape[1]
    S = k_new.shape[1]
    if c.ring and S > 1:
        # prefill into a ring: keep only the last min(S, cap) tokens
        take = min(S, cap)
        kt, vt = k_new[:, -take:], v_new[:, -take:]
        start0 = c.pos + S - take
        slots = jnp.mod(start0 + jnp.arange(take), cap)
        k = c.k.at[:, slots].set(kt)
        v = c.v.at[:, slots].set(vt)
    elif c.ring:
        slot = jnp.mod(c.pos, cap)
        k = jax.lax.dynamic_update_slice_in_dim(c.k, k_new, slot, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(c.v, v_new, slot, axis=1)
    else:
        k = jax.lax.dynamic_update_slice_in_dim(c.k, k_new, c.pos, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(c.v, v_new, c.pos, axis=1)
    return constrain_cache(AttnCache(k=k, v=v, pos=c.pos + S, ring=c.ring))


def cache_bytes(c: AttnCache) -> int:
    return c.k.size * c.k.dtype.itemsize * 2
