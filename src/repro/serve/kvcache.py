"""KV caches for serving: full, ring-buffer (sliding window), and cross-attn.

Sharding policy (decode-time memory dominates at 32k/500k):
  * batch axis -> ('pod', 'data')
  * KV heads   -> 'model' when divisible (GQA archs with >= mesh kv heads)
  * otherwise the SEQUENCE axis -> 'model' (length-sharded cache; attention
    over a length-sharded cache costs one small logits all-gather per step,
    but divides the dominant cache bytes by the TP degree).
This fallback is what makes e.g. llama3-8b decode_32k (8 kv heads, 16-way
model axis) fit: 4.3 GB/seq of cache is length-sharded instead of replicated.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.runtime import current_mesh

Array = jax.Array


@jax.tree_util.register_pytree_node_class
class AttnCache:
    """k, v: (B, C, Hkv, hd) with capacity C; pos: () int32 tokens written.
    `ring` (sliding-window buffer) is static pytree aux data, so it stays a
    python bool under jit/scan."""

    def __init__(self, k: Array, v: Array, pos: Array, ring: bool = False):
        self.k, self.v, self.pos, self.ring = k, v, pos, ring

    def _replace(self, **kw) -> "AttnCache":
        d = {"k": self.k, "v": self.v, "pos": self.pos, "ring": self.ring}
        d.update(kw)
        return AttnCache(**d)

    def tree_flatten(self):
        return (self.k, self.v, self.pos), self.ring

    @classmethod
    def tree_unflatten(cls, ring, children):
        return cls(*children, ring=ring)


class CrossCache(NamedTuple):
    k: Array      # (B, S_src, Hkv, hd) — fixed after prefill
    v: Array


def kv_pspec(batch: int, cap: int, heads: int) -> P:
    """Pick the cache PartitionSpec per the policy above."""
    mesh = current_mesh()
    if mesh is None:
        return P()
    bd = tuple(a for a in ("pod", "data") if a in mesh.shape)
    m = mesh.shape.get("model", 1)
    bspec = None
    prod = 1
    keep = []
    for a in bd:
        if batch % (prod * mesh.shape[a]) == 0:
            keep.append(a)
            prod *= mesh.shape[a]
    bspec = tuple(keep) if keep else None
    if m > 1 and heads % m == 0:
        return P(bspec, None, "model", None)
    if m > 1 and cap % m == 0:
        return P(bspec, "model", None, None)
    return P(bspec, None, None, None)


def constrain_cache(c: AttnCache) -> AttnCache:
    mesh = current_mesh()
    if mesh is None:
        return c
    spec = kv_pspec(c.k.shape[0], c.k.shape[1], c.k.shape[2])
    return c._replace(k=jax.lax.with_sharding_constraint(c.k, spec),
                      v=jax.lax.with_sharding_constraint(c.v, spec))


def cache_init(batch: int, cap: int, heads: int, hd: int, dtype,
               *, ring: bool = False, per_slot: bool = False) -> AttnCache:
    """`per_slot=True` gives the cache a PER-SLOT write position `(B,)`
    instead of the lockstep scalar — the continuous-batching engine's slots
    sit at different depths in their sequences, so every batch row appends
    at its own offset and masks with its own kv positions."""
    pos = jnp.zeros((batch,) if per_slot else (), jnp.int32)
    return AttnCache(
        k=jnp.zeros((batch, cap, heads, hd), dtype),
        v=jnp.zeros((batch, cap, heads, hd), dtype),
        pos=pos,
        ring=ring,
    )


def cache_positions(c: AttnCache) -> Array:
    """Absolute position stored in each slot; -1 marks unwritten/invalid.
    Scalar pos -> (cap,); per-slot pos (B,) -> (B, cap)."""
    cap = c.k.shape[1]
    slots = jnp.arange(cap, dtype=jnp.int32)
    pos = c.pos if c.pos.ndim == 0 else c.pos[:, None]
    if c.ring:
        # slot s holds the largest a < pos with a % cap == s
        a = pos - 1 - jnp.mod(pos - 1 - slots, cap)
        return jnp.where((a >= 0) & (pos > 0), a, -1)
    return jnp.where(slots < pos, slots, -1)


def _update_per_slot(c: AttnCache, k_new: Array, v_new: Array,
                     live: Optional[Array] = None) -> AttnCache:
    """Per-slot append: every batch row writes its S new tokens at its OWN
    position.  One scatter covers decode (S=1, B slots at B depths) and
    prefill-into-slot (B=1, S prompt tokens from pos 0).  Non-ring writes
    clamp at cap-1 — overfull rows are retired/zombie slots whose output is
    masked anyway, and clamping keeps the write in-bounds without a branch.

    `live` (B,) bool freezes dead rows bit-for-bit: their pos stays put and
    their scatter re-writes the bytes already in place.  With in-slot
    chunked prefill a dead row can be MID-PREFILL, so a zombie append is no
    longer harmless — it must not move the row's pos or bytes.

    The row-at-own-depth write is expressed as a VMAPPED per-row scatter,
    not `.at[rows, slot]` with concatenated (row, col) index pairs: vmap
    lowers to a scatter whose batch dim is explicit, which XLA's SPMD
    partitioner recognizes as index-parallel — under a slot-sharded pool
    (mesh serving, DESIGN.md §12) each shard scatters its own rows locally.
    The concatenated form defeats that analysis and inserts an all-gather +
    all-reduce around every layer's cache write; same values, same bytes,
    very different wire traffic."""
    cap = c.k.shape[1]
    S = k_new.shape[1]
    if c.ring and S > cap:  # keep only the in-window tail
        k_new, v_new = k_new[:, -cap:], v_new[:, -cap:]
        c = c._replace(pos=c.pos + (S - cap))
        S = cap
    abs_pos = c.pos[:, None] + jnp.arange(S, dtype=jnp.int32)  # (B, S)
    slot = jnp.mod(abs_pos, cap) if c.ring else jnp.clip(abs_pos, 0, cap - 1)
    step = S
    if live is not None:
        take = jax.vmap(lambda buf, s: buf[s])
        m = live[:, None, None, None]
        k_new = jnp.where(m, k_new, take(c.k, slot))
        v_new = jnp.where(m, v_new, take(c.v, slot))
        step = S * live.astype(c.pos.dtype)
    put = jax.vmap(lambda buf, s, new: buf.at[s].set(new))
    k = put(c.k, slot, k_new)
    v = put(c.v, slot, v_new)
    return constrain_cache(AttnCache(k=k, v=v, pos=c.pos + step, ring=c.ring))


def cache_update(c: AttnCache, k_new: Array, v_new: Array,
                 live: Optional[Array] = None) -> AttnCache:
    """Append S_new tokens (prefill: S_new = S; decode: S_new = 1).

    Non-ring: writes at [pos, pos+S).  Ring: writes each token at its
    (absolute position % window) slot; assumes S_new <= capacity or the
    early tokens are overwritten (correct: they'd be out of window anyway).
    With a per-slot pos (B,) every row appends at its own offset; `live`
    additionally freezes dead rows (continuous-batching decode tick).
    """
    cap = c.k.shape[1]
    S = k_new.shape[1]
    if c.pos.ndim == 1:
        return _update_per_slot(c, k_new, v_new, live)
    if live is not None:
        raise ValueError("live-masked cache updates need a per-slot pos")
    if c.ring and S > 1:
        # prefill into a ring: keep only the last min(S, cap) tokens
        take = min(S, cap)
        kt, vt = k_new[:, -take:], v_new[:, -take:]
        start0 = c.pos + S - take
        slots = jnp.mod(start0 + jnp.arange(take), cap)
        k = c.k.at[:, slots].set(kt)
        v = c.v.at[:, slots].set(vt)
    elif c.ring:
        slot = jnp.mod(c.pos, cap)
        k = jax.lax.dynamic_update_slice_in_dim(c.k, k_new, slot, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(c.v, v_new, slot, axis=1)
    else:
        k = jax.lax.dynamic_update_slice_in_dim(c.k, k_new, c.pos, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(c.v, v_new, c.pos, axis=1)
    return constrain_cache(AttnCache(k=k, v=v, pos=c.pos + S, ring=c.ring))


def cache_bytes(c: AttnCache) -> int:
    return c.k.size * c.k.dtype.itemsize * 2


# ---------------------------------------------------------------------------
# slot surgery (continuous batching, DESIGN.md §7): a cache row is a serving
# slot.  Admission copies a freshly prefilled B=1 cache into one row of the
# pool; retirement resets the row's position so its stale k/v are masked
# (cache_positions returns -1 past pos) rather than resliced.
# ---------------------------------------------------------------------------


def slot_axis(pool_shape, sub_shape) -> Optional[int]:
    """The axis where a batch-1 sub-state differs from the pool: that is
    the slot axis.  Equal shapes mean a 1-slot pool (whole replace).

    Public because the mesh serving layer keys off the same recovery:
    `launch.sharding.serve_pool_shardings` shards exactly this axis over
    the mesh's data axes, which is what keeps every per-slot scatter below
    (`write_row` / `read_row` — dynamic index on the slot axis only)
    index-parallel under SPMD instead of forcing a replication reshard."""
    if tuple(pool_shape) == tuple(sub_shape):
        return None
    for i, (p, s) in enumerate(zip(pool_shape, sub_shape)):
        if p != s:
            if s != 1:
                raise ValueError(f"sub-state axis {i} must be 1, got "
                                 f"{sub_shape} vs pool {pool_shape}")
            return i
    raise ValueError(f"no slot axis between {pool_shape} and {sub_shape}")


_slot_axis = slot_axis  # back-compat internal alias


def write_row(p: Array, s: Array, slot) -> Array:
    """Insert batch-1 leaf `s` into row `slot` of pool leaf `p` along the
    recovered slot axis (shapes are static under jit; `slot` is traced, so
    one compilation serves every admission)."""
    ax = _slot_axis(p.shape, s.shape)
    if ax is None:
        return s.astype(p.dtype)
    idx = (slice(None),) * ax + (slot,)
    return p.at[idx].set(jnp.squeeze(s, axis=ax).astype(p.dtype))


def read_row(p: Array, ref_shape, slot) -> Array:
    """Gather row `slot` of pool leaf `p` as a batch-1 leaf shaped like
    `ref_shape` (a batch-1 template shape — how the slot axis is recovered).
    The exact inverse of `write_row`: `write_row(p, read_row(p, r, s), s)`
    is the identity.  `slot` is traced, so one compilation serves every
    chunk of every admission."""
    ax = _slot_axis(p.shape, ref_shape)
    if ax is None:
        return p
    return jnp.take(p, jnp.asarray(slot, jnp.int32)[None], axis=ax)


def cache_gather_slot(c: AttnCache, ref: "AttnCache", slot) -> AttnCache:
    """Gather row `slot` of a per-slot cache pool as a batch-1 cache (the
    in-slot chunked prefill reads the slot, runs one prompt chunk, and
    writes the row back).  `ref` is a batch-1 template (arrays or
    ShapeDtypeStructs) fixing which axis is the slot axis per leaf."""
    return c._replace(k=read_row(c.k, ref.k.shape, slot),
                      v=read_row(c.v, ref.v.shape, slot),
                      pos=read_row(c.pos, ref.pos.shape, slot))


def cache_write_slot(c: AttnCache, sub: AttnCache, slot) -> AttnCache:
    """Insert a single-sequence cache (batch 1) into row `slot` of a
    per-slot pool.  `sub` must share the pool's capacity so the insert is a
    plain row copy — the engine prefills new requests against a pool-shaped
    B=1 cache for exactly this reason.  Works on a bare cache (slot axis 0)
    and on layer-stacked leaves (slot axis 1); the engine's generic
    `tree_write_slot` routes every AttnCache node through here."""
    pos = sub.pos if sub.pos.ndim else sub.pos[None]  # normalize scalar pos
    return c._replace(k=write_row(c.k, sub.k, slot),
                      v=write_row(c.v, sub.v, slot),
                      pos=write_row(c.pos, pos, slot))


def cache_reset_slots(c: AttnCache, mask: Array) -> AttnCache:
    """Retire slots where `mask` is True: per-slot pos drops to 0, so every
    kv position in the row reads as unwritten (-1) and attention masks it.
    k/v bytes are left in place — mask-don't-reshape keeps the decode step's
    shapes (and its jit trace) occupancy-independent."""
    return c._replace(pos=jnp.where(mask, 0, c.pos))


# ---------------------------------------------------------------------------
# prefix-state snapshots (DESIGN.md §10): the front door's prefix cache holds
# a gathered batch-1 slot state per cached prompt prefix.  For attention
# leaves only the FIRST `p` kv columns are live (non-ring, pos == p at a
# chunk boundary), so the stored entry narrows the cap axis to p — the cache
# budget pays for written history, not provisioned capacity — and splice-time
# widening zero-fills the tail, which per-slot pos masks exactly like the
# stale bytes `cache_reset_slots` leaves behind.  Ring caches are excluded by
# the engine's prefix-cache gate (a ring's live window need not start at 0).
# ---------------------------------------------------------------------------


def cache_narrow(c: AttnCache, p: int) -> AttnCache:
    """Keep only kv columns [0, p) of a gathered batch-1 cache.  `p` is a
    static chunk-boundary length; every row's pos must be <= p (true by
    construction: the engine snapshots right after the chunk that brought
    pos TO the boundary).  Works on a bare cache ((1, cap, H, hd), pos (1,))
    and a layer-stacked leaf ((L, 1, cap, H, hd), pos (L, 1)) — the cap axis
    is `pos.ndim` in both layouts."""
    if c.ring:
        raise ValueError("prefix snapshots need a non-ring cache")
    ax = c.pos.ndim
    sl = (slice(None),) * ax + (slice(0, p),)
    return c._replace(k=c.k[sl], v=c.v[sl])


def cache_widen(c: AttnCache, full_shape) -> AttnCache:
    """Inverse of `cache_narrow` up to the masked tail: zero-fill the cap
    axis back to the pool's provisioned capacity (`full_shape` is the
    batch-1 reference leaf shape) so the widened cache is row-copyable into
    a slot by the one-trace `cache_write_slot` path."""
    ax = c.pos.ndim
    p = c.k.shape[ax]
    if p == full_shape[ax]:
        return c
    k = jnp.zeros(full_shape, c.k.dtype)
    v = jnp.zeros(full_shape, c.v.dtype)
    idx = (slice(None),) * ax + (slice(0, p),)
    return c._replace(k=k.at[idx].set(c.k), v=v.at[idx].set(c.v))


# ---------------------------------------------------------------------------
# speculative-decoding suffix rewind (DESIGN.md §9): a verify step writes a
# span of K+1 candidate tokens at each slot's own depth; rejection rolls the
# suffix back.  Unlike bucket-pad rewind (pos arithmetic only), spec rollback
# also RESTORES the overwritten bytes from a pre-verify snapshot, so a
# rolled-back cache is bit-identical to one that never saw the rejected
# tokens — the rollback tests assert tree equality, not just masking.
# Non-ring caches only (a ring write could recycle in-window history, which
# no snapshot of the target span can restore); the engine gates speculative
# mode on `pad_buckets`, which encodes exactly "every cache is non-ring".
# ---------------------------------------------------------------------------


class SpecSnap(NamedTuple):
    """Rollback material for one AttnCache node: the k/v bytes the next
    `span` writes will overwrite (gathered at [pos, pos+span) per row) and
    the pre-verify positions."""
    k: Array
    v: Array
    pos: Array


def _span_slots(pos: Array, span: int, cap: int) -> Array:
    """(B, span) write slots of the next `span` tokens per row, clamped
    in-bounds like `_update_per_slot`'s non-ring append."""
    return jnp.clip(pos[:, None] + jnp.arange(span, dtype=jnp.int32),
                    0, cap - 1)


def cache_spec_snapshot(c: AttnCache, span: int) -> SpecSnap:
    """Gather the bytes a `span`-token verify is about to overwrite.  Works
    on a bare per-slot cache ((B, cap, H, hd), pos (B,)) and on a
    layer-stacked leaf ((L, B, cap, H, hd), pos (L, B)) via vmap."""
    if c.ring:
        raise ValueError("speculative rollback needs a non-ring cache "
                         "(a ring write recycles in-window history)")

    def one(k, v, pos):
        # vmapped per-row gather (not [rows, slot] concat-index pairs) so
        # the slot-sharded pool reads stay shard-local — see _update_per_slot
        slot = _span_slots(pos, span, k.shape[1])
        take = jax.vmap(lambda buf, s: buf[s])
        return take(k, slot), take(v, slot)

    if c.pos.ndim == 2:
        ks, vs = jax.vmap(one)(c.k, c.v, c.pos)
    else:
        ks, vs = one(c.k, c.v, c.pos)
    return SpecSnap(k=ks, v=vs, pos=c.pos)


def cache_spec_commit(c: AttnCache, snap: SpecSnap, keep: Array) -> AttnCache:
    """Commit `keep` (B,) of the span written since `snap` and roll the
    rest back: bytes past pos0+keep are restored from the snapshot and pos
    rewinds to pos0 + keep.  keep = 0 restores the snapshot bit-for-bit
    (reject-everything / dead-slot no-op); keep = span commits the whole
    verify.  The result is bit-identical to a cache that only ever wrote
    the accepted prefix."""
    # snapshot leaf mirrors the cache leaf with the cap axis narrowed to the
    # span: (B, span, H, hd) bare, (L, B, span, H, hd) stacked
    span = snap.k.shape[-3]

    def one(k, v, pos, sk, sv):
        # vmapped per-row gather+scatter, shard-local under a slot-sharded
        # pool — see _update_per_slot
        slot = _span_slots(pos, span, k.shape[1])
        take = jax.vmap(lambda buf, s: buf[s])
        put = jax.vmap(lambda buf, s, new: buf.at[s].set(new))
        m = (jnp.arange(span) < keep[:, None])[..., None, None]
        k = put(k, slot, jnp.where(m, take(k, slot), sk))
        v = put(v, slot, jnp.where(m, take(v, slot), sv))
        return k, v

    if c.pos.ndim == 2:
        k, v = jax.vmap(one, in_axes=(0, 0, 0, 0, 0))(
            c.k, c.v, snap.pos, snap.k, snap.v)
    else:
        k, v = one(c.k, c.v, snap.pos, snap.k, snap.v)
    return constrain_cache(c._replace(k=k, v=v, pos=snap.pos + keep))
