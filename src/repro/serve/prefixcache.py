"""Prefix-state cache for the serving front door (DESIGN.md §10).

Shared-prefix chat traffic (a system prompt repeated across requests) is the
workload where the RNN family's O(1) carried state pays off hardest: a
cached prefix is ONE (L, H) h/c row pair, and resuming from it is a single
`rnn_write_slots` row copy instead of re-prefilling the whole prefix.
Attention archs ride the same machinery with their kv columns narrowed to
the written history (`kvcache.cache_narrow`), so a cached transformer prefix
costs `p` columns of kv bytes, not a full provisioned row.

The cache is keyed on a hash of the token-id prefix at CHUNK-BUCKET
boundaries — exactly the offsets where the engine's chunked in-slot prefill
holds a complete, bit-exact carried state between chunks (§8).  On
admission the engine looks up the longest cached boundary prefix of the
prompt (capped at size-1: the last chunk must still run, because it samples
the request's first token), splices the entry's state into the slot, and
prefills only the remainder; on every full chunk that lands the engine
offers the gathered slot state back for insertion.

Exactness is inherited, not re-proven: a spliced state is bit-identical to
the state chunked prefill would have carried to that boundary (that is §8's
whole-vs-chunked contract), so hit-resume streams are byte-identical to
cold full prefills — asserted in tests/test_prefixcache.py.

Hash collisions cannot poison a stream: every entry stores the exact token
ids it was built from, and a lookup whose hash matches but whose ids differ
is rejected (counted in `collisions`) — the splice never trusts the digest
alone.  Eviction is LRU under a byte budget measured on the narrowed
on-device entries (target + draft state for speculative engines).

Mesh engines (DESIGN.md §12) share this cache unchanged: entries are
batch-1 states gathered through the engine's `_gather`, whose mesh
out-shardings REPLICATE the row, so a cached entry is placement-agnostic —
one cache can feed engines on different meshes, and the splice's pinned
in/out shardings put the widened row back on the slot's data shard without
retracing (splice_traces stays 1).
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Any, Optional, Tuple

import jax
import numpy as np

from repro.serve.kvcache import AttnCache, cache_narrow, cache_widen


def tree_bytes(tree: Any) -> int:
    """Bytes of every array leaf in a (possibly AttnCache-bearing) pytree."""
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(tree)
               if hasattr(l, "dtype"))


def narrow_state(sub: Any, p: int) -> Any:
    """Narrow a gathered batch-1 slot state for storage: AttnCache leaves
    keep only their first `p` kv columns; O(1) recurrent leaves (h/c,
    S-matrices, conv tails) are already minimal and pass through."""
    is_cache = lambda x: isinstance(x, AttnCache)
    return jax.tree.map(lambda l: cache_narrow(l, p) if is_cache(l) else l,
                        sub, is_leaf=is_cache)


def widen_state(sub: Any, ref: Any) -> Any:
    """Zero-fill narrowed AttnCache leaves back to the pool's provisioned
    capacity (`ref` is the engine's batch-1 shape template) so the splice is
    the same one-trace full-row write admission prefill uses."""
    is_cache = lambda x: isinstance(x, AttnCache)
    return jax.tree.map(
        lambda l, r: cache_widen(l, r.k.shape) if is_cache(l) else l,
        sub, ref, is_leaf=is_cache)


@dataclasses.dataclass
class PrefixEntry:
    tokens: np.ndarray          # the EXACT ids hashed — the poison guard
    state: Any                  # narrowed batch-1 target state (on device)
    draft_state: Optional[Any]  # lockstep draft state (speculative engines)
    nbytes: int


class PrefixCache:
    """LRU map: token-prefix digest -> carried slot state at that boundary.

    One cache may be shared by several engines (replicas serving the same
    model) as long as they agree on the chunk size and state layout —
    `bind(chunk)` pins the boundary stride on first use and refuses a
    mismatched engine afterwards.
    """

    def __init__(self, budget_bytes: int):
        if budget_bytes < 1:
            raise ValueError("prefix cache needs a positive byte budget")
        self.budget_bytes = int(budget_bytes)
        self.chunk: Optional[int] = None
        self._entries: "OrderedDict[str, PrefixEntry]" = OrderedDict()
        self.bytes = 0
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0       # prefill tokens splices made unnecessary
        self.insertions = 0
        self.evictions = 0
        self.collisions = 0       # digest matched, stored ids did not

    def bind(self, chunk: int) -> None:
        if self.chunk is None:
            self.chunk = int(chunk)
        elif self.chunk != int(chunk):
            raise ValueError(
                f"prefix cache is bound to chunk={self.chunk}; an engine "
                f"with prefill_chunk={chunk} would key incompatible "
                f"boundaries")

    @staticmethod
    def _key(tokens: np.ndarray) -> str:
        t = np.ascontiguousarray(np.asarray(tokens, np.int32))
        return hashlib.blake2b(t.tobytes() + t.size.to_bytes(8, "little"),
                               digest_size=16).hexdigest()

    def __len__(self) -> int:
        return len(self._entries)

    def contains(self, tokens: np.ndarray) -> bool:
        """Digest-presence check only (no LRU touch, no counters) — the
        engine uses it to skip the device gather when a boundary it just
        crossed is already cached."""
        return self._key(tokens) in self._entries

    def lookup(self, prompt: np.ndarray,
               limit: Optional[int] = None) -> Tuple[int, Optional[PrefixEntry]]:
        """Longest cached boundary prefix of `prompt`, searched from
        floor(min(limit, len-1) / chunk) * chunk downward in chunk strides.
        Returns (p, entry); (0, None) on miss.  A hit refreshes LRU order;
        an id mismatch at a matching digest is a collision, never a hit."""
        assert self.chunk is not None, "bind(chunk) before lookup"
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        cap = prompt.size - 1 if limit is None else min(limit, prompt.size - 1)
        p = (cap // self.chunk) * self.chunk
        if p < self.chunk:
            return 0, None  # no cacheable boundary exists for this prompt
        while p >= self.chunk:
            key = self._key(prompt[:p])
            e = self._entries.get(key)
            if e is not None:
                if not np.array_equal(e.tokens, prompt[:p]):
                    self.collisions += 1
                elif e.tokens.size != p:  # defensive: key encodes size too
                    self.collisions += 1
                else:
                    self._entries.move_to_end(key)
                    self.hits += 1
                    self.hit_tokens += p
                    return p, e
            p -= self.chunk
        self.misses += 1
        return 0, None

    def insert(self, tokens: np.ndarray, state: Any,
               draft_state: Optional[Any] = None) -> bool:
        """Store the carried state for prefix `tokens`.  Re-inserting a
        present key refreshes its LRU position (the state at a boundary is
        deterministic, so the stored entry is already correct).  Entries are
        evicted oldest-first until the budget holds; an entry larger than
        the whole budget is refused."""
        tokens = np.asarray(tokens, np.int32).reshape(-1).copy()
        key = self._key(tokens)
        if key in self._entries:
            self._entries.move_to_end(key)
            return False
        nbytes = tree_bytes(state) + tree_bytes(draft_state)
        if nbytes > self.budget_bytes:
            return False
        while self.bytes + nbytes > self.budget_bytes and self._entries:
            _, old = self._entries.popitem(last=False)
            self.bytes -= old.nbytes
            self.evictions += 1
        self._entries[key] = PrefixEntry(tokens=tokens, state=state,
                                         draft_state=draft_state,
                                         nbytes=nbytes)
        self.bytes += nbytes
        self.insertions += 1
        return True

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "entries": len(self._entries),
            "bytes": self.bytes,
            "budget_bytes": self.budget_bytes,
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
            "hit_tokens": self.hit_tokens,
            "insertions": self.insertions,
            "evictions": self.evictions,
            "collisions": self.collisions,
        }
