"""Asyncio HTTP/SSE front door over the continuous-batching engine
(DESIGN.md §10).

    POST /v1/generate          body: {"prompt": [ids], "max_tokens": N,
                                      "temperature": t, "top_k": k,
                                      "seed": s, "priority": p, "slo": "..."}
                               -> text/event-stream, one `data:` event per
                                  scheduler iteration that sampled tokens
                                  for this request, then `event: done`
    GET  /v1/stats             -> engine counters + prefix-cache stats

The server is a single asyncio task pool over `asyncio.start_server` — no
HTTP framework, because the serving container ships none and the protocol
surface here is tiny.  One PUMP task drives the engine's resumable step API:
it calls `engine.step()` whenever work exists and fans the returned
(rid, tokens) events out to per-request queues; connection handlers
`submit()` on POST and consume their queue into SSE frames.  Everything
runs on ONE event loop thread, so submit/cancel/step interleave at
iteration granularity and need no locking — the engine itself stays
single-threaded, exactly as the fuzz harness drives it.  (A device tick
blocks the loop for its duration; the tick IS the unit of service, so
nothing finer-grained exists to schedule anyway.)

DISCONNECTS: each streaming handler watches its reader for EOF while it
waits for tokens.  A client that hangs up mid-stream — or whose SSE write
fails — gets `engine.cancel(rid)`: queued requests are dropped before they
touch a slot, in-flight ones are retired through the SAME batched
shape-aware scrub normal retirement uses, so the freed slot reads exactly
like a fresh one and the next occupant's prefill cannot see the dead
request's state.  Cancellation triggers no new jit traces (asserted in
tests/test_frontdoor.py).

Tokens stream as ids, not text: the repo has no tokenizer dependency and
the paper's PTB/wiki vocabularies are word-level anyway; a real deployment
maps ids to text at the edge.

`python -m repro.serve.frontdoor --smoke` runs the CI smoke: start a tiny
ternary-LSTM server on localhost, stream one request to completion, cancel
a second mid-stream by hanging up, re-send a shared-system-prompt request
and assert the prefix cache served its prefix — the full front-door
contract in one process, no external client needed.
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import json
import sys
from typing import Dict, Optional, Tuple

import numpy as np

from repro.serve.engine import Request, ServeEngine

_MAX_BODY = 1 << 20  # a 1 MiB prompt is ~260k int32 tokens — far past any
                     # context this engine provisions; bigger is a bad client


# ---------------------------------------------------------------------------
# minimal HTTP/1.1 plumbing
# ---------------------------------------------------------------------------


async def _read_request(reader) -> Optional[Tuple[str, str, dict, bytes]]:
    """Parse one HTTP/1.1 request (start line, headers, Content-Length
    body).  Returns None on EOF/garbage — the handler just closes."""
    try:
        line = await reader.readline()
        parts = line.decode("ascii", "replace").split()
        if len(parts) < 2:
            return None
        method, path = parts[0].upper(), parts[1]
        headers = {}
        while True:
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            k, _, v = h.decode("ascii", "replace").partition(":")
            headers[k.strip().lower()] = v.strip()
        n = int(headers.get("content-length", "0") or 0)
        if n < 0 or n > _MAX_BODY:
            return None
        body = await reader.readexactly(n) if n else b""
        return method, path, headers, body
    except (asyncio.IncompleteReadError, ConnectionError, ValueError):
        return None


def _response(status: str, ctype: str, body: bytes,
              stream: bool = False) -> bytes:
    head = [f"HTTP/1.1 {status}", f"Content-Type: {ctype}",
            "Cache-Control: no-store", "Connection: close"]
    if not stream:
        head.append(f"Content-Length: {len(body)}")
    return ("\r\n".join(head) + "\r\n\r\n").encode("ascii") + body


def _json_response(status: str, obj) -> bytes:
    return _response(status, "application/json",
                     (json.dumps(obj) + "\n").encode())


def _sse(data, event: Optional[str] = None) -> bytes:
    frame = (f"event: {event}\n" if event else "") + \
        f"data: {json.dumps(data)}\n\n"
    return frame.encode()


# ---------------------------------------------------------------------------
# the front door
# ---------------------------------------------------------------------------


class FrontDoor:
    """One engine, one listener, one pump.  `await start()`, then
    `await serve_forever()` (or drive the returned server yourself);
    `await close()` drains nothing — in-flight requests are cancelled the
    way a dead client would cancel them."""

    def __init__(self, engine: ServeEngine, host: str = "127.0.0.1",
                 port: int = 8700):
        self.engine = engine
        self.host, self.port = host, int(port)
        self._streams: Dict[int, asyncio.Queue] = {}
        self._wake = asyncio.Event()
        self._closing = False
        self._server: Optional[asyncio.AbstractServer] = None
        self._pump_task: Optional[asyncio.Task] = None

    # -- engine pump --------------------------------------------------------

    async def _pump(self) -> None:
        """The scheduler loop as an asyncio task: one `engine.step()` per
        iteration while work exists, fan the sampled tokens out to the
        per-request stream queues, park on an Event when idle.  The
        `sleep(0)` between steps is the handlers' window to submit and
        cancel — the same between-iterations granularity `run()` gives the
        batch driver."""
        while not self._closing:
            if not self.engine.has_work():
                self._wake.clear()
                if self.engine.has_work():  # submit raced the clear
                    continue
                await self._wake.wait()
                continue
            events, comps = self.engine.step()
            for rid, toks in events:
                q = self._streams.get(rid)
                if q is not None:
                    q.put_nowait(("tokens", toks))
            for c in comps:
                q = self._streams.get(c.rid)
                if q is not None:
                    q.put_nowait(("done", c))
            await asyncio.sleep(0)

    # -- connection handling ------------------------------------------------

    async def _handle(self, reader, writer) -> None:
        try:
            req = await _read_request(reader)
            if req is None:
                return
            method, path, _, body = req
            if method == "POST" and path == "/v1/generate":
                await self._generate(reader, writer, body)
            elif method == "GET" and path == "/v1/stats":
                writer.write(_json_response("200 OK", self.engine.stats()))
                await writer.drain()
            else:
                writer.write(_json_response("404 Not Found",
                                            {"error": f"no route {path}"}))
                await writer.drain()
        except (ConnectionError, asyncio.CancelledError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    def _parse_request(self, body: bytes) -> Request:
        o = json.loads(body.decode())
        prompt = np.asarray(o["prompt"], np.int32)
        return Request(prompt=prompt,
                       max_tokens=int(o["max_tokens"]),
                       temperature=float(o.get("temperature", 0.8)),
                       top_k=int(o.get("top_k", 0)),
                       seed=int(o.get("seed", 0)),
                       priority=int(o.get("priority", 0)),
                       slo=str(o.get("slo", "default")))

    async def _generate(self, reader, writer, body: bytes) -> None:
        try:
            req = self._parse_request(body)
            rid = self.engine.submit(req)
        except (KeyError, TypeError, ValueError, json.JSONDecodeError) as e:
            writer.write(_json_response("400 Bad Request",
                                        {"error": str(e)}))
            await writer.drain()
            return
        q: asyncio.Queue = asyncio.Queue()
        self._streams[rid] = q
        self._wake.set()
        # EOF watch: a well-behaved client sends nothing after the POST
        # body, so the ONLY way this read completes is the client hanging
        # up — which must cancel the request, whatever phase it is in
        hangup = asyncio.ensure_future(reader.read(1))
        try:
            writer.write(_response("200 OK", "text/event-stream", b"",
                                   stream=True))
            writer.write(_sse({"rid": rid}, event="accepted"))
            await writer.drain()
            while True:
                get = asyncio.ensure_future(q.get())
                done, _ = await asyncio.wait(
                    {get, hangup}, return_when=asyncio.FIRST_COMPLETED)
                if get not in done:  # client hung up mid-stream
                    get.cancel()
                    self.engine.cancel(rid)
                    return
                kind, payload = get.result()
                if kind == "tokens":
                    writer.write(_sse({"rid": rid, "tokens": payload}))
                    await writer.drain()
                else:  # ('done', Completion)
                    c = payload
                    writer.write(_sse(
                        {"rid": rid, "finished": c.finished,
                         "n_tokens": len(c.tokens),
                         "prompt_len": c.prompt_len,
                         "cached_tokens": c.cached_tokens, "slo": c.slo,
                         "ttft_s": c.ttft_s, "latency_s": c.latency_s},
                        event="done"))
                    await writer.drain()
                    return
        except (ConnectionError, OSError):
            # the SSE write itself failed: same as a hangup
            self.engine.cancel(rid)
        finally:
            hangup.cancel()
            self._streams.pop(rid, None)

    # -- lifecycle ----------------------------------------------------------

    async def start(self) -> None:
        self._pump_task = asyncio.ensure_future(self._pump())
        self._server = await asyncio.start_server(self._handle, self.host,
                                                  self.port)
        if self.port == 0:  # ephemeral: report what the OS picked
            self.port = self._server.sockets[0].getsockname()[1]

    async def serve_forever(self) -> None:
        async with self._server:
            await self._server.serve_forever()

    async def close(self) -> None:
        self._closing = True
        self._wake.set()
        if self._pump_task is not None:
            self._pump_task.cancel()
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()


# ---------------------------------------------------------------------------
# smoke client + entry point (the CI front-door step)
# ---------------------------------------------------------------------------


async def _post_stream(host: str, port: int, payload: dict, *,
                       hangup_after: Optional[int] = None):
    """Raw-socket SSE client: POST /v1/generate, collect streamed token ids.
    With `hangup_after`, close the socket after that many token events —
    the disconnect path the front door must turn into a cancel."""
    reader, writer = await asyncio.open_connection(host, port)
    body = json.dumps(payload).encode()
    writer.write((f"POST /v1/generate HTTP/1.1\r\nHost: {host}\r\n"
                  f"Content-Type: application/json\r\n"
                  f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
    await writer.drain()
    toks, done, events = [], None, 0
    buf = b""
    while True:
        chunk = await reader.read(4096)
        if not chunk:
            break
        buf += chunk
        while b"\n\n" in buf:
            frame, buf = buf.split(b"\n\n", 1)
            dat = [l[5:] for l in frame.split(b"\n") if l.startswith(b"data:")]
            if not dat:
                continue
            o = json.loads(dat[0])
            if "tokens" in o:
                toks.extend(o["tokens"])
                events += 1
                if hangup_after is not None and events >= hangup_after:
                    writer.close()
                    return toks, None
            elif "finished" in o:
                done = o
        if done is not None:
            break
    writer.close()
    return toks, done


async def _get_json(host: str, port: int, path: str) -> dict:
    reader, writer = await asyncio.open_connection(host, port)
    writer.write(f"GET {path} HTTP/1.1\r\nHost: {host}\r\n\r\n".encode())
    await writer.drain()
    raw = await reader.read()
    writer.close()
    return json.loads(raw.split(b"\r\n\r\n", 1)[1])


def _smoke_engine():
    """A tiny packed-ternary LSTM engine with a prefix cache — small enough
    for a CI minute, real enough to exercise every front-door path."""
    import jax

    from repro.core import bnlstm as BL
    from repro.core.quantize import QuantSpec
    from repro.serve.prefixcache import PrefixCache
    from repro.serve.recurrent import RNNRuntime

    cfg = BL.RNNConfig(vocab=32, d_hidden=48, n_layers=2, cell="lstm",
                       quant=QuantSpec(mode="ternary", norm="batch"))
    var = BL.rnn_lm_init(jax.random.PRNGKey(0), cfg)
    params = BL.export_packed_rnn(var["params"], cfg)
    rt = RNNRuntime(cfg, {"params": params, "state": var["state"]})
    eng = ServeEngine(rt, cfg.vocab, slots=2, max_context=96,
                      prefill_chunk=8, prefix_cache=PrefixCache(1 << 24))
    eng.warm([8, 24])
    return eng


async def _smoke(port: int) -> int:
    eng = _smoke_engine()
    fd = FrontDoor(eng, port=port)
    await fd.start()
    host, port = fd.host, fd.port
    rng = np.random.default_rng(7)
    system = rng.integers(0, 32, size=16).tolist()  # shared "system prompt"
    ok = True

    def check(cond, msg):
        nonlocal ok
        print(("PASS " if cond else "FAIL ") + msg)
        ok = ok and cond

    # 1. stream one request to completion
    toks, done = await _post_stream(host, port, {
        "prompt": system + rng.integers(0, 32, size=4).tolist(),
        "max_tokens": 12, "seed": 1})
    check(done is not None and len(toks) == 12 == done["n_tokens"],
          f"streamed request completed ({len(toks)} tokens)")

    # 2. cancel a second mid-stream by hanging up after 3 token events
    await _post_stream(host, port, {
        "prompt": rng.integers(0, 32, size=10).tolist(),
        "max_tokens": 40, "seed": 2}, hangup_after=3)
    await asyncio.sleep(0.2)  # let the pump observe the hangup
    stats = await _get_json(host, port, "/v1/stats")
    check(stats["active"] == 0 and stats["queued"] == 0,
          "hangup cancelled the in-flight request and freed its slot")
    check(stats["tick_traces"] == 1,
          f"tick compiled once across cancel churn "
          f"(traces={stats['tick_traces']})")

    # 3. repeat the system prompt with a fresh tail: the prefix cache must
    # serve the shared prefix (request 1 inserted its chunk boundaries)
    hits0 = stats["prefix_cache"]["hits"]
    toks3, done3 = await _post_stream(host, port, {
        "prompt": system + rng.integers(0, 32, size=5).tolist(),
        "max_tokens": 6, "seed": 3})
    stats = await _get_json(host, port, "/v1/stats")
    check(done3 is not None and len(toks3) == 6,
          "shared-prefix request completed")
    check(stats["prefix_cache"]["hits"] > hits0
          and done3.get("cached_tokens", 0) >= 8,
          f"prefix cache hit on the repeated system prompt "
          f"(cached_tokens={done3.get('cached_tokens')})")

    await fd.close()
    print("front-door smoke:", "OK" if ok else "FAILED")
    return 0 if ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="start a tiny in-process server, run the stream/"
                         "cancel/prefix-hit smoke against it, exit 0/1")
    ap.add_argument("--port", type=int, default=0,
                    help="listen port (0 = ephemeral)")
    args = ap.parse_args(argv)
    if not args.smoke:
        ap.error("standalone serving lives in `python -m repro.launch.serve "
                 "--listen`; this entry point only runs --smoke")
    return asyncio.run(_smoke(args.port))


if __name__ == "__main__":
    sys.exit(main())
