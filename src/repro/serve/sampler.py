"""Token samplers for the serving loop."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def sample(logits: Array, key: Array, *, temperature: float = 1.0,
           top_k: int = 0, vocab: int = 0) -> Array:
    """logits: (B, V) -> (B,) int32.  temperature<=0 means greedy.

    Masking uses the dtype's own minimum — a hard-coded -1e30 overflows to
    -inf under bf16/fp16 logits, and a whole row of -inf breaks
    `jax.random.categorical` (NaN probabilities)."""
    neg = jnp.finfo(logits.dtype).min
    if vocab and logits.shape[-1] > vocab:
        logits = jnp.where(jnp.arange(logits.shape[-1]) < vocab, logits, neg)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits >= kth, logits, neg)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
