"""Token samplers for the serving loop.

`sample` is the scalar-config path (one temperature/top_k/key for the whole
batch) the lockstep launcher uses.  `sample_slots` is the per-slot vectorized
form the continuous-batching engine uses: every slot carries its own
temperature, top-k and PRNG key, and a slot's draw is bit-identical to what
`sample` would produce for that request alone — that equivalence is what
makes engine-vs-sequential token parity possible (tests/test_serve_engine.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def sample(logits: Array, key: Array, *, temperature: float = 1.0,
           top_k: int = 0, vocab: int = 0) -> Array:
    """logits: (B, V) -> (B,) int32.  temperature<=0 means greedy.

    Masking uses the dtype's own minimum — a hard-coded -1e30 overflows to
    -inf under bf16/fp16 logits, and a whole row of -inf breaks
    `jax.random.categorical` (NaN probabilities)."""
    neg = jnp.finfo(logits.dtype).min
    if vocab and logits.shape[-1] > vocab:
        logits = jnp.where(jnp.arange(logits.shape[-1]) < vocab, logits, neg)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits >= kth, logits, neg)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def sample_slots(logits: Array, keys: Array, *, temperature: Array,
                 top_k: Array, vocab: int = 0) -> Array:
    """Per-slot sampling: logits (B, V), keys (B, 2), temperature (B,) fp,
    top_k (B,) int32 -> (B,) int32.

    Row semantics match `sample(logits[i:i+1], keys[i], temperature[i],
    top_k[i])` bit-for-bit: the vocab mask and temperature scaling are the
    same elementwise ops, the k-th-largest threshold comes from a descending
    sort (identical values to `lax.top_k`, but the static-k constraint is
    gone so per-slot k never retraces), and the categorical draw under vmap
    generates the same threefry bits as the B=1 call (counter-based bits
    depend only on the flat element count, and (1, V) flattens to (V,)).
    temperature <= 0 means greedy for that slot; top_k <= 0 disables the
    top-k filter for that slot."""
    V = logits.shape[-1]
    neg = jnp.finfo(logits.dtype).min
    if vocab and V > vocab:
        logits = jnp.where(jnp.arange(V) < vocab, logits, neg)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    t = temperature.astype(logits.dtype)[:, None]
    scaled = logits / jnp.where(t > 0, t, jnp.ones_like(t))
    desc = -jnp.sort(-scaled, axis=-1)  # descending: desc[:, k-1] = kth largest
    kth = jnp.take_along_axis(
        desc, jnp.clip(top_k - 1, 0, V - 1)[:, None], axis=-1)
    filtered = jnp.where(scaled >= kth, scaled, neg)
    final = jnp.where((top_k > 0)[:, None], filtered, scaled)
    drawn = jax.vmap(lambda k, l: jax.random.categorical(k, l))(keys, final)
    return jnp.where(temperature > 0, drawn.astype(jnp.int32), greedy)
