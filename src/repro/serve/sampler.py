"""Token samplers for the serving loop.

`sample` is the scalar-config path (one temperature/top_k/key for the whole
batch) the lockstep launcher uses.  `sample_slots` is the per-slot vectorized
form the continuous-batching engine uses: every slot carries its own
temperature, top-k and PRNG key, and a slot's draw is bit-identical to what
`sample` would produce for that request alone — that equivalence is what
makes engine-vs-sequential token parity possible (tests/test_serve_engine.py).

The speculative-decoding half (DESIGN.md §9) lives here too:
`filtered_probs` turns per-slot logits into the EXACT distribution
`sample_slots` draws from (a one-hot at temperature <= 0), `residual_probs`
is the Leviathan rejection-sampling residual max(p-q, 0)/Z, and
`spec_accept` applies the accept-while-`u < p/q` rule across a whole batch
of slots at once.  Because the temp-0 distributions are exact one-hots, the
generic rule degenerates to "accept iff the draft matched the target's
argmax, resample = the argmax" — greedy speculative decoding is byte-
identical to plain greedy decoding with no special case in the engine.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def sample(logits: Array, key: Array, *, temperature: float = 1.0,
           top_k: int = 0, vocab: int = 0) -> Array:
    """logits: (B, V) -> (B,) int32.  temperature<=0 means greedy.

    Masking uses the dtype's own minimum — a hard-coded -1e30 overflows to
    -inf under bf16/fp16 logits, and a whole row of -inf breaks
    `jax.random.categorical` (NaN probabilities)."""
    neg = jnp.finfo(logits.dtype).min
    if vocab and logits.shape[-1] > vocab:
        logits = jnp.where(jnp.arange(logits.shape[-1]) < vocab, logits, neg)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits >= kth, logits, neg)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def _filtered(logits: Array, temperature: Array, top_k: Array,
              vocab: int = 0):
    """The per-slot filtering pipeline shared by `sample_slots` and
    `filtered_probs`: vocab mask, temperature scaling, sort-based top-k.
    Returns (final masked/scaled logits, per-slot greedy argmax) — the
    greedy comes from the vocab-masked logits BEFORE temperature/top-k,
    exactly what a temperature<=0 slot samples."""
    V = logits.shape[-1]
    neg = jnp.finfo(logits.dtype).min
    if vocab and V > vocab:
        logits = jnp.where(jnp.arange(V) < vocab, logits, neg)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    t = temperature.astype(logits.dtype)[:, None]
    scaled = logits / jnp.where(t > 0, t, jnp.ones_like(t))
    desc = -jnp.sort(-scaled, axis=-1)  # descending: desc[:, k-1] = kth largest
    kth = jnp.take_along_axis(
        desc, jnp.clip(top_k - 1, 0, V - 1)[:, None], axis=-1)
    filtered = jnp.where(scaled >= kth, scaled, neg)
    final = jnp.where((top_k > 0)[:, None], filtered, scaled)
    return final, greedy


def sample_slots(logits: Array, keys: Array, *, temperature: Array,
                 top_k: Array, vocab: int = 0) -> Array:
    """Per-slot sampling: logits (B, V), keys (B, 2), temperature (B,) fp,
    top_k (B,) int32 -> (B,) int32.

    Row semantics match `sample(logits[i:i+1], keys[i], temperature[i],
    top_k[i])` bit-for-bit: the vocab mask and temperature scaling are the
    same elementwise ops, the k-th-largest threshold comes from a descending
    sort (identical values to `lax.top_k`, but the static-k constraint is
    gone so per-slot k never retraces), and the categorical draw under vmap
    generates the same threefry bits as the B=1 call (counter-based bits
    depend only on the flat element count, and (1, V) flattens to (V,)).
    temperature <= 0 means greedy for that slot; top_k <= 0 disables the
    top-k filter for that slot."""
    final, greedy = _filtered(logits, temperature, top_k, vocab)
    drawn = jax.vmap(lambda k, l: jax.random.categorical(k, l))(keys, final)
    return jnp.where(temperature > 0, drawn.astype(jnp.int32), greedy)


# ---------------------------------------------------------------------------
# speculative decoding: rejection-sampling acceptance (DESIGN.md §9)
# ---------------------------------------------------------------------------


def filtered_probs(logits: Array, temperature: Array, top_k: Array,
                   vocab: int = 0) -> Array:
    """The probability distribution `sample_slots` actually draws from:
    softmax of the vocab-masked, temperature-scaled, top-k-filtered logits
    per slot, and an EXACT one-hot at the greedy argmax where
    temperature <= 0.  logits (B, V) -> probs (B, V) float32.

    The one-hot is what makes greedy speculation byte-exact: with p and q
    both one-hots, the accept ratio p(d)/q(d) is exactly 1 or 0 and the
    residual collapses to the target argmax, so the generic rejection rule
    IS plain greedy decoding."""
    final, greedy = _filtered(logits.astype(jnp.float32), temperature,
                              top_k, vocab)
    probs = jax.nn.softmax(final, axis=-1)
    onehot = jax.nn.one_hot(greedy, logits.shape[-1], dtype=probs.dtype)
    return jnp.where((temperature > 0)[:, None], probs, onehot)


def residual_probs(p: Array, q: Array) -> Array:
    """The rejection-sampling residual distribution norm(max(p - q, 0)).

    p, q: (..., V) probability rows.  Where the residual has zero mass
    (p == q up to rounding — a rejection there has probability ~0 but a
    float `u` can still land on it), fall back to p itself so the draw
    stays a valid sample from the target."""
    r = jnp.maximum(p - q, 0.0)
    s = jnp.sum(r, axis=-1, keepdims=True)
    return jnp.where(s > 0, r / jnp.where(s > 0, s, 1.0), p)


def categorical_slots(keys: Array, probs: Array) -> Array:
    """Per-slot categorical draw from PROBABILITY rows (not logits):
    probs (B, V), keys (B, 2) -> (B,) int32.  A one-hot row draws its hot
    index with probability 1 (log turns the zeros into -inf)."""
    drawn = jax.vmap(lambda k, p: jax.random.categorical(k, jnp.log(p)))(
        keys, probs)
    return drawn.astype(jnp.int32)


def spec_accept(p_logits: Array, q_logits: Array, drafts: Array, keys: Array,
                *, temperature: Array, top_k: Array, vocab: int = 0):
    """Leviathan-style accept/reject over a batch of slots.

    p_logits: (B, K+1, V) target logits at every verify position (position
              K is the bonus position after all K drafts);
    q_logits: (B, K, V)  draft logits the proposals were sampled from;
    drafts:   (B, K)     proposed tokens;
    keys:     (B, 2)     per-slot round keys;
    temperature/top_k: (B,) per-slot sampling params (the SAME filtering is
              applied to p and q, so the accepted stream follows the
              target's post-filter sampling distribution exactly).

    Returns (n_acc (B,) int32, out (B, K+1) int32): slot b emits
    out[b, :n_acc[b]] — its accepted draft prefix plus ONE trailing token
    (the residual resample at the first rejection, or the bonus draw when
    every draft survived).  1 <= n_acc <= K+1 always: a verify step never
    emits zero tokens.  Entries past n_acc are junk and must not be read."""
    B, Kp1, V = p_logits.shape
    K = Kp1 - 1
    per_pos = jax.vmap(
        lambda lg: filtered_probs(lg, temperature, top_k, vocab),
        in_axes=1, out_axes=1)
    P = per_pos(p_logits)                      # (B, K+1, V)
    Q = per_pos(q_logits)                      # (B, K, V)

    ks = jax.vmap(lambda k: jax.random.split(k, 3))(keys)      # (B, 3, 2)
    u = jax.vmap(lambda k: jax.random.uniform(k, (K,)))(ks[:, 0])

    pd = jnp.take_along_axis(P[:, :K], drafts[..., None], axis=-1)[..., 0]
    qd = jnp.take_along_axis(Q, drafts[..., None], axis=-1)[..., 0]
    # u < p(d)/q(d), written divide-free: P(accept) = min(1, p/q) exactly,
    # and q(d) = 0 (junk rows) rejects instead of dividing by zero.  With
    # one-hot p/q the ratio is exactly 1 or 0, and uniform u in [0, 1)
    # always accepts ratio 1 — greedy acceptance is deterministic.
    accept = u * qd < pd                                       # (B, K)
    n_d = jnp.cumprod(accept.astype(jnp.int32), axis=1).sum(axis=1)
    n_acc = n_d + 1

    # the trailing token: residual resample at the first rejected position,
    # or the bonus draw from the position-K target distribution
    pos = jnp.minimum(n_d, K - 1)[:, None, None]
    p_rej = jnp.take_along_axis(P[:, :K], pos, axis=1)[:, 0]   # (B, V)
    q_rej = jnp.take_along_axis(Q, pos, axis=1)[:, 0]
    t_res = categorical_slots(ks[:, 1], residual_probs(p_rej, q_rej))
    t_bonus = categorical_slots(ks[:, 2], P[:, K])
    final = jnp.where(n_d == K, t_bonus, t_res)

    cols = jnp.arange(K + 1, dtype=n_d.dtype)[None]
    dpad = jnp.concatenate(
        [drafts, jnp.zeros((B, 1), drafts.dtype)], axis=1)
    out = jnp.where(cols == n_d[:, None], final[:, None], dpad)
    return n_acc.astype(jnp.int32), out.astype(jnp.int32)
