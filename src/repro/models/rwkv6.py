"""RWKV-6 "Finch" mixer — linear attention with data-dependent per-channel
decay (arXiv:2404.05892), chunked for TPU.

The WKV6 recurrence per head (head size N):

    y_t = r_t . (S_{t-1} + diag(u) k_t^T v_t)
    S_t = diag(w_t) S_{t-1} + k_t^T v_t          w_t in (0,1), data-dependent

is evaluated chunk-wise: within a chunk of Q tokens it becomes a causally
masked (Q x Q) matmul against cumulative log-decays (all exp arguments are
<= 0, so no overflow), across chunks a `lax.scan` carries the (H, N, N)
state — same MXU-friendly decomposition as Mamba2's SSD.

Quantizable 'W*' leaves: Wr, Wk, Wv, Wg, Wo (time mix) and Wck, Wcv, Wcr
(channel mix).  The decay/mix LoRAs (rank 32/64) and u-bonus are O(d) fp —
the paper's own biases/BN-params-stay-fp split.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ops import qmatmul
from repro.core.qlinear import maybe_scale, scaled, winit
from repro.runtime import constrain

Array = jax.Array

LORA_R = 32


class RWKVState(NamedTuple):
    S: Array         # (B, H, N, N) wkv state
    tm_shift: Array  # (B, d) last token seen by time-mix
    cm_shift: Array  # (B, d) last token seen by channel-mix
    pos: Array


def rwkv6_init(key, cfg) -> dict:
    d = cfg.d_model
    N = cfg.hd
    H = d // N
    ks = jax.random.split(key, 12)
    p = {
        # time mix
        "Wr": winit(ks[0], (d, d)), "Wk": winit(ks[1], (d, d)),
        "Wv": winit(ks[2], (d, d)), "Wg": winit(ks[3], (d, d)),
        "Wo": winit(ks[4], (d, d)),
        "mu_x": jnp.full((5, d), 0.5),       # r,k,v,w,g shift-mix coefficients
        "lora_A": jax.random.normal(ks[5], (d, LORA_R * 5)) * 0.01,
        "lora_B": jnp.zeros((5, LORA_R, d)),
        "w0": jnp.linspace(-6.0, -1.0, d),   # decay bias (log-log space)
        "wA": jax.random.normal(ks[6], (d, 2 * LORA_R)) * 0.01,
        "wB": jnp.zeros((2 * LORA_R, d)),
        "u": jnp.zeros((H, N)),              # bonus
        "ln_x": jnp.ones((d,)),              # per-head group-norm scale
        # channel mix
        "Wck": winit(ks[7], (d, cfg.d_ff)),
        "Wcv": winit(ks[8], (cfg.d_ff, d)),
        "Wcr": winit(ks[9], (d, d)),
        "mu_ck": jnp.full((d,), 0.5), "mu_cr": jnp.full((d,), 0.5),
    }
    for n, dout in (("Wr", d), ("Wk", d), ("Wv", d), ("Wg", d), ("Wo", d),
                    ("Wck", cfg.d_ff), ("Wcv", d), ("Wcr", d)):
        maybe_scale(p, n, cfg.quant, dout, jnp.float32)
    return p


def wkv6_chunked(r: Array, k: Array, v: Array, logw: Array, u: Array,
                 chunk: int, S0: Optional[Array] = None) -> Tuple[Array, Array]:
    """r,k,v: (B, T, H, N); logw: (B, T, H, N) (<=0); u: (H, N).
    Returns (y (B,T,H,N), S_final (B,H,N,N))."""
    Bsz, T, H, N = r.shape
    Q = min(chunk, T)
    T0 = T
    if T % Q:
        # zero-pad to a chunk multiple: k=v=0 adds nothing to the state and
        # logw=0 (decay 1) leaves it untouched, so the final state is exact.
        pad = Q - T % Q
        z = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, logw = z(r), z(k), z(v), z(logw)
        T = T + pad
    nc = T // Q
    rs = lambda t: t.reshape(Bsz, nc, Q, H, N)
    r, k, v, logw = rs(r), rs(k), rs(v), rs(logw)

    L = jnp.cumsum(logw, axis=2)            # inclusive cumulative log decay
    Lm1 = L - logw                          # exclusive (L_{i-1}); row 0 -> 0
    Lend = L[:, :, -1]                      # (B, nc, H, N)

    # intra-chunk, strictly causal: att[i,j] = (r_i * exp(Lm1_i - L_j)) . k_j
    ri = r * jnp.exp(Lm1)                   # decayed queries
    kj = k * jnp.exp(-L)                    # inverse-decayed keys (<= factor 1 net)
    att = jnp.einsum("bcihn,bcjhn->bchij", ri, kj)
    idx = jnp.arange(Q)
    att = jnp.where((idx[:, None] > idx[None, :])[None, None, None], att, 0.0)
    # diagonal bonus term: (r_i * u) . k_i
    diag = jnp.einsum("bcihn,hn,bcihn->bchi", r, u, k)
    y = jnp.einsum("bchij,bcjhn->bcihn", att, v)
    y = y + jnp.einsum("bchi,bcihn->bcihn", diag, v)

    # chunk state increments: sum_j diag(exp(Lend - L_j)) k_j^T v_j.
    # The recurrent state is ALWAYS fp32 (compounded decays drift in bf16).
    kdec = k * jnp.exp(Lend[:, :, None] - L)
    inc = jnp.einsum("bcjhn,bcjhm->bchnm", kdec, v).astype(jnp.float32)

    if S0 is None:
        S0 = jnp.zeros((Bsz, H, N, N), jnp.float32)
    S0 = S0.astype(jnp.float32)

    def step(S, inp):
        inc_c, dec_c = inp                  # (B,H,N,N), (B,H,N)
        S_in = S
        S = S * jnp.exp(dec_c)[..., None] + inc_c
        return S, S_in

    ST, S_in = jax.lax.scan(step, S0,
                            (jnp.moveaxis(inc, 1, 0), jnp.moveaxis(Lend, 1, 0)))
    S_in = jnp.moveaxis(S_in, 0, 1)         # (B, nc, H, N, N)

    y = y + jnp.einsum("bcihn,bchnm->bcihm", ri, S_in)
    return y.reshape(Bsz, T, H, N)[:, :T0], ST


def wkv6_step(r, k, v, logw, u, S):
    """Single token: r,k,v,logw (B,H,N); S (B,H,N,N) fp32 -> (y, S').

    This IS the serving decode_step body (serve/recurrent.py): one outer
    product + one state-weighted readout per head, no sequence axis."""
    S = S.astype(jnp.float32)
    kv = jnp.einsum("bhn,bhm->bhnm", k, v).astype(jnp.float32)
    y = jnp.einsum("bhn,bhnm->bhm", r.astype(jnp.float32),
                   S + u.astype(jnp.float32)[None, :, :, None] * kv)
    S = S * jnp.exp(logw).astype(jnp.float32)[..., None] + kv
    return y, S


def _group_norm(x: Array, scale: Array, H: int) -> Array:
    """Per-head LayerNorm over the head dim (rwkv's ln_x)."""
    B, T, d = x.shape
    xh = x.reshape(B, T, H, d // H).astype(jnp.float32)
    mu = jnp.mean(xh, axis=-1, keepdims=True)
    var = jnp.var(xh, axis=-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + 1e-5)
    return (xh.reshape(B, T, d) * scale).astype(x.dtype)


def rwkv6_time_mix(p: dict, x: Array, cfg, *, state: Optional[RWKVState] = None,
                   decode: Optional[bool] = None):
    """decode=None auto-selects for direct mixer callers: a single
    carried-state token takes the `wkv6_step` recurrence, longer slices the
    chunked scan.  The transformer block driver passes the flag explicitly
    (its prefill forces the chunked path even at S=1)."""
    B, T, d = x.shape
    N = cfg.hd
    H = d // N
    if decode is None:
        decode = state is not None and T == 1

    prev = state.tm_shift if state is not None else jnp.zeros((B, d), x.dtype)
    xprev = jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)
    sx = xprev - x

    # data-dependent mixing (Finch): 5 deltas from a shared LoRA
    xxx = x + sx * p["mu_x"][0]  # use mu_r as the probe mix (cheap, faithful shape)
    lora = jnp.tanh(xxx @ p["lora_A"].astype(x.dtype)).reshape(B, T, 5, LORA_R)
    delta = jnp.einsum("btfr,frd->btfd", lora, p["lora_B"].astype(x.dtype))
    mix = p["mu_x"].astype(x.dtype)[None, None] + delta      # (B, T, 5, d)
    xr, xk, xv, xw, xg = [(x + sx * mix[:, :, i]).astype(x.dtype)
                          for i in range(5)]

    r = scaled(qmatmul(xr, p["Wr"]), p, "Wr", cfg.quant).reshape(B, T, H, N)
    k = scaled(qmatmul(xk, p["Wk"]), p, "Wk", cfg.quant).reshape(B, T, H, N)
    v = scaled(qmatmul(xv, p["Wv"]), p, "Wv", cfg.quant).reshape(B, T, H, N)
    g = jax.nn.silu(scaled(qmatmul(xg, p["Wg"]), p, "Wg", cfg.quant))

    # data-dependent decay: w = exp(-exp(w0 + lora_w(xw))), logw <= 0 (fp32)
    ww = p["w0"] + (jnp.tanh(xw @ p["wA"].astype(x.dtype))
                    @ p["wB"].astype(x.dtype)).astype(jnp.float32)
    logw = -jnp.exp(jnp.clip(ww, -10.0, 5.0)).reshape(B, T, H, N)

    r = constrain(r, ("pod", "data"), None, "model", None)
    k = constrain(k, ("pod", "data"), None, "model", None)
    v = constrain(v, ("pod", "data"), None, "model", None)

    S0 = state.S if state is not None else None
    if decode:
        S0 = S0 if S0 is not None else jnp.zeros((B, H, N, N), x.dtype)
        y1, ST = wkv6_step(r[:, 0], k[:, 0], v[:, 0], logw[:, 0], p["u"], S0)
        y = y1[:, None]
    else:
        y, ST = wkv6_chunked(r, k, v, logw, p["u"], cfg.ssm_chunk, S0)

    y = _group_norm(y.reshape(B, T, d), p["ln_x"], H) * g
    out = scaled(qmatmul(y, p["Wo"]), p, "Wo", cfg.quant)
    return out, ST, x[:, -1]


def rwkv6_channel_mix(p: dict, x: Array, cfg, *, prev: Optional[Array] = None):
    B, T, d = x.shape
    if prev is None:
        prev = jnp.zeros((B, d), x.dtype)
    xprev = jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)
    sx = xprev - x
    xk = x + sx * p["mu_ck"].astype(x.dtype)
    xr = x + sx * p["mu_cr"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(scaled(qmatmul(xk, p["Wck"]), p, "Wck", cfg.quant)))
    k = constrain(k, ("pod", "data"), None, "model")
    kv = scaled(qmatmul(k, p["Wcv"]), p, "Wcv", cfg.quant)
    return jax.nn.sigmoid(scaled(qmatmul(xr, p["Wcr"]), p, "Wcr", cfg.quant)) * kv, x[:, -1]


def state_init(cfg, batch: int, dtype=jnp.float32, *,
               per_slot: bool = False) -> RWKVState:
    """Zero per-session recurrent state — the unified serving-state entry
    point (one signature with `mamba2.state_init` / `bnlstm.rnn_state_init`;
    serve/recurrent.py and the transformer cache builder both use it).
    `per_slot` makes the token counter (B,) so every continuous-batching
    slot tracks its own depth; `pos` is bookkeeping, not compute, so the
    wkv recurrence is unchanged either way."""
    d = cfg.d_model
    N = cfg.hd
    H = d // N
    return RWKVState(S=jnp.zeros((batch, H, N, N), jnp.float32),  # fp32 core
                     tm_shift=jnp.zeros((batch, d), dtype),
                     cm_shift=jnp.zeros((batch, d), dtype),
                     pos=jnp.zeros((batch,) if per_slot else (), jnp.int32))


rwkv_state_init = state_init  # historical name
