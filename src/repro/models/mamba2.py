"""Mamba2 (SSD) mixer — chunked selective-state-space recurrence.

Used by `zamba2-1.2b` (hybrid).  TPU-native formulation:

  * the sequence is processed in chunks of `cfg.ssm_chunk`; within a chunk the
    recurrence is a dense (Q x Q) causally-masked matmul (MXU work), across
    chunks a `lax.scan` carries the (H, N, P) state — this is the standard
    SSD block-decomposition and maps the "recurrence" onto matmuls instead of
    a length-S scalar scan (length-S scans are VPU-serial on TPU).
  * in/out projections are 'W*' quantizable leaves (the paper's technique);
    the SSM dynamics parameters (A, dt bias, conv, D) are O(d) and stay fp,
    mirroring the paper keeping biases/BN parameters full-precision.

Shapes: d_inner = expand * d_model, H = d_inner / headdim ssm heads,
N = ssm_state, single B/C group (zamba2 uses n_groups=1).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ops import qmatmul
from repro.core.qlinear import maybe_scale, scaled, winit
from repro.runtime import constrain

Array = jax.Array


class SSMState(NamedTuple):
    h: Array        # (B, H, N, P) inter-chunk state
    conv: Array     # (B, K-1, conv_dim) causal-conv tail
    pos: Array      # () int32 — tokens seen


def _dims(cfg):
    di = cfg.d_inner
    H = cfg.ssm_heads
    P = cfg.ssm_headdim
    N = cfg.ssm_state
    conv_dim = di + 2 * N  # x, B, C pass through the causal conv
    return di, H, P, N, conv_dim


def mamba2_init(key, cfg) -> dict:
    d = cfg.d_model
    di, H, P, N, conv_dim = _dims(cfg)
    d_proj = 2 * di + 2 * N + H  # z, x, B, C, dt
    ki, ko, kc, kd = jax.random.split(key, 4)
    p = {
        "Win": winit(ki, (d, d_proj)),
        "Wout": winit(ko, (di, d)),
        "conv_w": jax.random.normal(kc, (cfg.ssm_conv, conv_dim)) * 0.1,
        "conv_b": jnp.zeros((conv_dim,)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)),  # A = -exp(A_log)
        "D": jnp.ones((H,)),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(kd, (H,)) * (jnp.log(0.1) - jnp.log(1e-3))
                    + jnp.log(1e-3)))),
        "norm": jnp.ones((di,)),
    }
    maybe_scale(p, "Win", cfg.quant, d_proj, jnp.float32)
    maybe_scale(p, "Wout", cfg.quant, d, jnp.float32)
    return p


def _causal_conv(x: Array, w: Array, b: Array, tail: Optional[Array] = None):
    """x: (B, S, C) depthwise causal conv with kernel (K, C).  `tail` is the
    last K-1 inputs from the previous call (decode); returns (y, new_tail)."""
    K = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    new_tail = xp[:, -(K - 1):, :] if K > 1 else tail
    y = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype) for i in range(K))
    return jax.nn.silu(y + b.astype(x.dtype)), new_tail


def ssd_chunked(x: Array, dt: Array, A: Array, Bm: Array, Cm: Array,
                chunk: int, h0: Optional[Array] = None) -> Tuple[Array, Array]:
    """Chunked SSD scan.

    x:  (B, S, H, P)   dt: (B, S, H)   A: (H,) (negative)
    Bm, Cm: (B, S, N)  (single group, broadcast over heads)
    h0: optional (B, H, N, P) initial state.
    Returns (y (B, S, H, P), h_final (B, H, N, P)).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    S0_len = S
    if S % Q:
        # zero-pad to a chunk multiple: dt=0 gives decay exp(0)=1 and zero
        # state increment, so the final state is exact.
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q

    r = lambda t: t.reshape(Bsz, nc, Q, *t.shape[2:])
    x, dt, Bm, Cm = r(x), r(dt), r(Bm), r(Cm)

    dA = dt * A  # (B, nc, Q, H) — negative
    cum = jnp.cumsum(dA, axis=2)
    seg_end = cum[:, :, -1, :]                     # total chunk decay (log)

    # intra-chunk: att[i,j] = (C_i . B_j) * exp(cum_i - cum_j) * dt_j, i >= j
    idx = jnp.arange(Q)
    causal = idx[:, None] >= idx[None, :]
    logdec = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Qi,Qj,H)
    dec = jnp.where(causal[None, None, :, :, None], jnp.exp(logdec), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", Cm, Bm)               # (B,nc,Qi,Qj)
    att = cb[..., None] * dec * dt[:, :, None, :, :]         # (B,nc,Qi,Qj,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att, x)

    # chunk states: S_c = sum_j exp(seg_end - cum_j) * dt_j * B_j x_j^T
    w_state = jnp.exp(seg_end[:, :, None, :] - cum) * dt     # (B,nc,Q,H)
    states = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", w_state, Bm, x)

    # inter-chunk scan over nc (tiny: S/Q iterations of an (H,N,P) op).
    # The recurrent state is ALWAYS fp32 (decay products compound; bf16
    # states drift over long contexts and break the scan carry dtype).
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, N, P), jnp.float32)
    h0 = h0.astype(jnp.float32)
    states = states.astype(jnp.float32)

    def step(h, inp):
        st, dec_tot = inp  # (B,H,N,P), (B,H)
        h_out = h  # state entering this chunk
        h = h * jnp.exp(dec_tot)[:, :, None, None] + st
        return h, h_out

    hT, h_in = jax.lax.scan(step, h0,
                            (jnp.moveaxis(states, 1, 0), jnp.moveaxis(seg_end, 1, 0)))
    h_in = jnp.moveaxis(h_in, 0, 1)                          # (B,nc,H,N,P)

    # inter-chunk contribution: y_i += C_i . (exp(cum_i) * h_in)
    y_inter = jnp.einsum("bcin,bchnp,bcih->bcihp", Cm, h_in, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y[:, :S0_len], hT


def ssd_step(x: Array, dt: Array, A: Array, Bm: Array, Cm: Array, h: Array):
    """Single-token recurrence (decode).  x: (B, H, P), dt: (B, H),
    Bm/Cm: (B, N), h: (B, H, N, P) -> (y, h').

    This IS the serving decode_step body (serve/recurrent.py): decay, rank-1
    state update, readout — no sequence axis."""
    dA = jnp.exp(dt * A)                                     # (B, H)
    inc = jnp.einsum("bh,bn,bhp->bhnp", dt, Bm, x)
    h = h * dA[:, :, None, None] + inc
    y = jnp.einsum("bn,bhnp->bhp", Cm, h)
    return y, h


def mamba2_apply(p: dict, x: Array, cfg, *, state: Optional[SSMState] = None,
                 decode: Optional[bool] = None) -> Tuple[Array, Optional[SSMState]]:
    """x: (B, S, d_model). decode=True expects S == 1 and a state;
    decode=None auto-selects the `ssd_step` path for a single carried-state
    token (direct mixer callers; the transformer block driver passes the
    flag explicitly)."""
    Bsz, S, d = x.shape
    di, H, P, N, conv_dim = _dims(cfg)
    if decode is None:
        decode = state is not None and S == 1

    proj = scaled(qmatmul(x, p["Win"]), p, "Win", cfg.quant)
    z, xin, Bc, Cc, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])

    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    tail = state.conv if state is not None else None
    conv_out, new_tail = _causal_conv(conv_in, p["conv_w"], p["conv_b"], tail)
    xin, Bc, Cc = jnp.split(conv_out, [di, di + N], axis=-1)

    xh = xin.reshape(Bsz, S, H, P)
    xh = constrain(xh, ("pod", "data"), None, "model", None)
    h0 = state.h if state is not None else None

    if decode:
        h0 = (h0 if h0 is not None
              else jnp.zeros((Bsz, H, N, P), jnp.float32)).astype(jnp.float32)
        y1, hT = ssd_step(xh[:, 0].astype(jnp.float32), dt[:, 0], A,
                          Bc[:, 0].astype(jnp.float32),
                          Cc[:, 0].astype(jnp.float32), h0)
        y = y1[:, None]
    else:
        y, hT = ssd_chunked(xh, dt, A, Bc, Cc, cfg.ssm_chunk, h0)

    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xh.astype(y.dtype)
    y = y.reshape(Bsz, S, di)

    # gated RMSNorm (mamba2): norm(y * silu(z))
    g = y * jax.nn.silu(z)
    g32 = g.astype(jnp.float32)
    g = (g32 * jax.lax.rsqrt(jnp.mean(g32 * g32, axis=-1, keepdims=True) + 1e-6)
         ).astype(x.dtype) * p["norm"].astype(x.dtype)

    out = scaled(qmatmul(g, p["Wout"]), p, "Wout", cfg.quant)
    new_state = None
    if state is not None or decode:
        pos = (state.pos if state is not None else jnp.zeros((), jnp.int32)) + S
        new_state = SSMState(h=hT, conv=new_tail, pos=pos)
    return out, new_state


def state_init(cfg, batch: int, dtype=jnp.float32, *,
               per_slot: bool = False) -> SSMState:
    """Zero per-session recurrent state — the unified serving-state entry
    point (one signature with `rwkv6.state_init` / `bnlstm.rnn_state_init`;
    serve/recurrent.py and the transformer cache builder both use it).
    `per_slot` makes the token counter (B,) so every continuous-batching
    slot tracks its own depth; `pos` is bookkeeping, not compute, so the
    SSD recurrence is unchanged either way."""
    di, H, P, N, conv_dim = _dims(cfg)
    return SSMState(
        h=jnp.zeros((batch, H, N, P), jnp.float32),  # fp32 recurrent core
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        pos=jnp.zeros((batch,) if per_slot else (), jnp.int32),
    )


ssm_state_init = state_init  # historical name
