"""Mixture-of-Experts FFN (mixtral / qwen3-moe style): grouped capacity-based
dense dispatch, shardable as EP over the 'model' mesh axis.

Design notes (TPU / pjit):
  * Routing is computed in fp32; the router weight 'router' stays
    full-precision (accuracy-critical, <0.1% of params — DESIGN.md §5).
  * GROUPED dispatch: tokens are split into groups of <= `group_size`
    (sharded over the data axes) and routed with per-group capacity — the
    standard Switch/GShard formulation.  The dispatch one-hots are
    (G, Tg, E, C) so their footprint is bounded per group; an UNGROUPED
    one-hot at 1M tokens/step would be O(T*E*C) ~ 10^13 elements.
  * Dispatch/combine are einsums, so every tensor keeps static shapes, the
    expert axis is a real array axis (pjit shards it over 'model' when E
    divides the axis, lowering the exchange to all-to-alls) and, when E is
    smaller than the axis (mixtral: 8 experts on 16 chips), the expert
    matmuls fall back to plain tensor parallelism over d_ff.
  * Expert weights are 'W*' leaves (E, d, f): the paper's binary/ternary
    quantizer applies per expert matrix via quantize_tree, unchanged.
  * Capacity overflow drops tokens (training); the decode path passes
    no_drop=True (capacity = Tg) because drops would corrupt sampling.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.qlinear import maybe_scale, scaled, winit
from repro.core.qtensor import QTensor
from repro.kernels.ops import qmatmul
from repro.runtime import constrain, current_mesh

Array = jax.Array

GROUP_SIZE = 4096  # tokens per routing group
CAP_ALIGN = 128    # capacity rounded up to the MXU tile (also makes the
                   # capacity axis model-shardable when E doesn't divide)


def moe_init(key, cfg) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    kr, kg, ku, kd = jax.random.split(key, 4)
    p = {
        "router": jax.random.normal(kr, (d, e), jnp.float32) * (d ** -0.5),
        "Wgate": winit(kg, (e, d, f)),
        "Wup": winit(ku, (e, d, f)),
        "Wdown": winit(kd, (e, f, d)),
    }
    for n, dout in (("Wgate", f), ("Wup", f), ("Wdown", d)):
        maybe_scale(p, n, cfg.quant, dout, jnp.float32)
    return p


def capacity(n_tokens: int, cfg, align: int = 1) -> int:
    c = int(math.ceil(cfg.topk * n_tokens / cfg.n_experts * cfg.capacity_factor))
    c = max(c, cfg.topk)  # at least topk slots so tiny tests route
    return (c + align - 1) // align * align


def route(logits: Array, cfg, cap: int) -> Tuple[Array, Array, Array]:
    """logits: (T, E) fp32 -> (dispatch (T, E, C), combine (T, E, C), aux)."""
    T, E = logits.shape
    gates, idx = jax.lax.top_k(logits, cfg.topk)           # (T, k)
    gates = jax.nn.softmax(gates, axis=-1)                  # normalize over k

    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)      # (T, k, E)

    # queue position per (token, k): cumsum over tokens, k-major priority
    oh_kt = jnp.swapaxes(onehot, 0, 1).reshape(cfg.topk * T, E)
    pos_kt = jnp.cumsum(oh_kt, axis=0) - oh_kt
    pos = jnp.swapaxes(pos_kt.reshape(cfg.topk, T, E), 0, 1)  # (T, k, E)
    keep = (pos < cap) & (onehot > 0)

    slot = jax.nn.one_hot(jnp.sum(pos * onehot, axis=-1).astype(jnp.int32),
                          cap, dtype=jnp.float32)           # (T, k, C)
    disp = jnp.einsum("tke,tkc->tec", onehot * keep, slot)
    comb = jnp.einsum("tke,tkc->tec", onehot * keep * gates[..., None], slot)

    probs = jax.nn.softmax(logits, axis=-1)
    frac_tokens = jnp.mean(onehot[:, 0, :], axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return disp, comb, aux


def _expert_mm(xe: Array, w) -> Array:
    """(G, E, C, d) @ per-expert (E, d, f) -> (G, E, C, f), fp or QTensor.

    The fp path keeps the single einsum (one fused contraction, expert axis
    shardable); a packed QTensor runs per-expert through qmatmul, which
    unrolls the expert axis over the Pallas kernel."""
    if isinstance(w, QTensor):
        xE = jnp.moveaxis(xe, 1, 0)          # (E, G, C, d)
        return jnp.moveaxis(qmatmul(xE, w), 0, 1)
    return jnp.einsum("gecd,edf->gecf", xe, w)


def moe_apply(p: dict, x: Array, cfg, *, no_drop: bool = False,
              group_size: int = GROUP_SIZE) -> Tuple[Array, Array]:
    """x: (B, S, d) -> (y, aux_loss).  SwiGLU experts, grouped routing."""
    B, S, d = x.shape
    T = B * S
    Tg = min(group_size, T)
    if T % Tg:
        Tg = T  # odd tiny shapes: single group
    G = T // Tg
    xt = x.reshape(G, Tg, d)
    xt = constrain(xt, ("pod", "data"), None, None)
    align = CAP_ALIGN if T >= CAP_ALIGN * cfg.n_experts else 1
    cap = Tg if no_drop else capacity(Tg, cfg, align)

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32), p["router"])
    disp, comb, aux = jax.vmap(lambda l: route(l, cfg, cap))(logits)
    aux = jnp.mean(aux)
    disp = disp.astype(x.dtype)
    comb = comb.astype(x.dtype)

    # Shard the expert axis over 'model' when it divides; otherwise shard the
    # CAPACITY axis (mixtral: 8 experts on 16-way TP).  Without the fallback
    # the dispatch/combine einsums replicate across the model axis — measured
    # 7.6x flop inflation on mixtral train (EXPERIMENTS.md §Perf).
    mesh = current_mesh()
    m = mesh.shape.get("model", 1) if mesh is not None else 1
    if m > 1 and cfg.n_experts % m == 0:
        espec = ("model", None)
    else:
        espec = (None, "model")

    # dispatch: (G, E, C, d) — groups sharded over data
    xe = jnp.einsum("gtd,gtec->gecd", xt, disp)
    xe = constrain(xe, ("pod", "data"), *espec, None)

    g = _expert_mm(xe, p["Wgate"])
    u = _expert_mm(xe, p["Wup"])
    g = scaled(g, p, "Wgate", cfg.quant)
    u = scaled(u, p, "Wup", cfg.quant)
    h = jax.nn.silu(g) * u
    h = constrain(h, ("pod", "data"), *espec, None)
    ye = scaled(_expert_mm(h, p["Wdown"]), p, "Wdown", cfg.quant)
    ye = constrain(ye, ("pod", "data"), *espec, None)

    y = jnp.einsum("gecd,gtec->gtd", ye, comb)
    return y.reshape(B, S, d), aux.astype(jnp.float32)
