"""Shared transformer building blocks: RMSNorm, RoPE, chunked GQA attention
(full / sliding-window / cross), SwiGLU & GeLU MLPs.

All matmul weights follow the default 'W*' pattern of the QuantPolicy in
`repro.core.quantize`; by the time these functions run, a weight may be a
plain fp array, binary/ternary values produced by `quantize_tree` (the
paper's technique), or an exported packed `QTensor` — every weight matmul
goes through `kernels.ops.qmatmul`, which dispatches on the operand, so the
layer code is agnostic.

Attention is query-chunked (a scan over query blocks) so peak logits memory is
O(chunk x S) instead of O(S x S); sliding-window layers additionally slice the
KV stream to `window + chunk`, making local attention O(S x window) — both
matter for the pod-scale memory analysis and keep the HLO small.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.qlinear import maybe_scale, scaled, winit
from repro.kernels.ops import qmatmul
from repro.runtime import constrain

Array = jax.Array
NEG_INF = -1e30


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale)).astype(dt)


def rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, hd); positions: (S,) or broadcastable to x's S axis."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., :, None, :]  # broadcast over heads
    sin = sin[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))
    return pe


# ---------------------------------------------------------------------------
# core scaled-dot-product with flexible masking, fp32 softmax
# ---------------------------------------------------------------------------


def _sdpa(q: Array, k: Array, v: Array, q_pos: Array, kv_pos: Array,
          *, causal: bool, window: int, softcap: float = 0.0) -> Array:
    """q: (B, Sq, Hq, hd); k, v: (B, Skv, Hkv, hd) with Hq % Hkv == 0;
    *_pos: (Sq,), (Skv,) absolute positions (kv_pos < 0 marks invalid /
    unwritten cache slots) — or per-sequence (B, Sq) / (B, Skv) when slots
    of a continuous-batching pool sit at different depths; the mask then
    varies over batch instead of broadcasting.

    GQA is computed by grouping q heads (einsum over (Hkv, G)) instead of
    materializing repeated K/V — repeating would (a) multiply decode-time KV
    bytes by G and (b) force a cache reshard when the cache is length-sharded
    (SPMD 'involuntary full rematerialization', EXPERIMENTS.md §Perf)."""
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd)
    scale = hd ** -0.5
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32)
    logits = logits * scale
    if softcap > 0.0:
        logits = jnp.tanh(logits / softcap) * softcap
    qp = q_pos if q_pos.ndim == 2 else q_pos[None]    # (B|1, Sq)
    kp = kv_pos if kv_pos.ndim == 2 else kv_pos[None]  # (B|1, Skv)
    mask = (kp[:, None, :] >= 0)
    if causal:
        mask = mask & (kp[:, None, :] <= qp[:, :, None])
    if window > 0:
        mask = mask & (kp[:, None, :] > qp[:, :, None] - window)
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return o.reshape(B, Sq, Hq, hd)


def attention(q: Array, k: Array, v: Array, *, causal: bool = True,
              window: int = 0, q_offset=0, kv_pos: Optional[Array] = None,
              chunk: int = 1024, softcap: float = 0.0) -> Array:
    """Grouped-query attention with query chunking.

    q: (B, Sq, Hq, hd); k, v: (B, Skv, Hkv, hd) with Hq % Hkv == 0.
    `q_offset` is the absolute position of q[0] (decode: cache length) —
    scalar, or (B,) when a per-slot cache puts every sequence at its own
    depth.  `kv_pos` gives absolute positions of cache slots (ring buffers);
    (Skv,) or per-slot (B, Skv); defaults to arange(Skv).
    """
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    if kv_pos is None:
        kv_pos = jnp.arange(Skv, dtype=jnp.int32)
    off = jnp.asarray(q_offset, jnp.int32)
    ar = jnp.arange(Sq, dtype=jnp.int32)
    q_pos = off[:, None] + ar if off.ndim == 1 else off + ar
    batched = q_pos.ndim == 2 or kv_pos.ndim == 2

    if Sq <= chunk or Sq % chunk != 0:
        return _sdpa(q, k, v, q_pos, kv_pos, causal=causal, window=window, softcap=softcap)

    n_chunks = Sq // chunk
    # the sliding-window KV slice needs one scalar start per chunk, so it
    # stays off when positions are per-row; query chunking itself is
    # row-independent and still bounds logits memory for a long
    # prefill-into-slot (admission prefills are B=1 but Sq can be the
    # whole prompt)
    use_slice = window > 0 and Skv > window + chunk and causal and not batched
    kv_span = window + chunk if use_slice else Skv

    def one(i):
        qi = jax.lax.dynamic_slice_in_dim(q, i * chunk, chunk, axis=1)
        if q_pos.ndim == 2:
            qp = jax.lax.dynamic_slice_in_dim(q_pos, i * chunk, chunk, axis=1)
        else:
            qp = q_pos[0] + i * chunk + jnp.arange(chunk, dtype=jnp.int32)
        if use_slice:
            start = jnp.clip(q_offset + i * chunk - window + 1, 0, Skv - kv_span)
            ki = jax.lax.dynamic_slice_in_dim(k, start, kv_span, axis=1)
            vi = jax.lax.dynamic_slice_in_dim(v, start, kv_span, axis=1)
            kp = start + jnp.arange(kv_span, dtype=jnp.int32)
        else:
            ki, vi, kp = k, v, kv_pos
        return _sdpa(qi, ki, vi, qp, kp, causal=causal, window=window, softcap=softcap)

    out = jax.lax.map(one, jnp.arange(n_chunks))  # (n_chunks, B, chunk, Hq, hd)
    return jnp.moveaxis(out, 0, 1).reshape(B, Sq, Hq, hd)


# ---------------------------------------------------------------------------
# attention layer (params + apply)
# ---------------------------------------------------------------------------


def attn_init(key, cfg, *, cross: bool = False, kv_d: Optional[int] = None) -> dict:
    d, hd = cfg.d_model, cfg.hd
    kq, kk, kv_, ko, kg = jax.random.split(key, 5)
    kvd = kv_d or d
    p = {
        "Wq": winit(kq, (d, cfg.n_heads * hd)),
        "Wk": winit(kk, (kvd, cfg.n_kv * hd)),
        "Wv": winit(kv_, (kvd, cfg.n_kv * hd)),
        "Wo": winit(ko, (cfg.n_heads * hd, d)),
    }
    for n, dout in (("Wq", cfg.n_heads * hd), ("Wk", cfg.n_kv * hd),
                    ("Wv", cfg.n_kv * hd), ("Wo", d)):
        maybe_scale(p, n, cfg.quant, dout, jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,))
        p["k_norm"] = jnp.zeros((hd,))
    if cross:
        p["xgate"] = jnp.zeros(())  # tanh-gated cross-attn (llama-vision style)
    return p


def attn_q(p: dict, x: Array, cfg) -> Array:
    """Query projection only (decode-time cross attention)."""
    hd = cfg.hd
    B, S, _ = x.shape
    q = scaled(qmatmul(x, p["Wq"]), p, "Wq", cfg.quant).reshape(B, S, cfg.n_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
    return q


def attn_kv(p: dict, src: Array, cfg):
    """Key/value projections (cache fill / cross-source encode)."""
    hd = cfg.hd
    B, S, _ = src.shape
    k = scaled(qmatmul(src, p["Wk"]), p, "Wk", cfg.quant).reshape(B, S, cfg.n_kv, hd)
    v = scaled(qmatmul(src, p["Wv"]), p, "Wv", cfg.quant).reshape(B, S, cfg.n_kv, hd)
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"])
    return k, v


def attn_qkv(p: dict, x: Array, cfg, kv_src: Optional[Array] = None):
    """Project to q (from x) and k,v (from kv_src or x); returns (q, k, v)."""
    src = x if kv_src is None else kv_src
    q = attn_q(p, x, cfg)
    k, v = attn_kv(p, src, cfg)
    return q, k, v


def attn_out(p: dict, o: Array, cfg, *, cross: bool = False) -> Array:
    B, S = o.shape[:2]
    o = o.reshape(B, S, cfg.n_heads * cfg.hd)
    y = scaled(qmatmul(o, p["Wo"]), p, "Wo", cfg.quant)
    if cross and "xgate" in p:
        y = jnp.tanh(p["xgate"]).astype(y.dtype) * y
    return y


def attn_apply(p: dict, x: Array, cfg, *, kind: str = "full",
               positions: Optional[Array] = None,
               kv_src: Optional[Array] = None, chunk: int = 1024,
               causal: Optional[bool] = None) -> Array:
    """Self- or cross-attention over a full sequence (training / prefill)."""
    B, S, d = x.shape
    cross = kind == "cross"
    q, k, v = attn_qkv(p, x, cfg, kv_src=kv_src if cross else None)
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    if not cross:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("pod", "data"), None, "model", None)
    window = cfg.window if kind == "local" or (kind == "full" and cfg.window and cfg.swa_all) else 0
    if causal is None:
        causal = cfg.causal and not cross
    o = attention(q, k, v, causal=causal, window=window, chunk=chunk,
                  softcap=cfg.attn_softcap)
    return attn_out(p, o, cfg, cross=cross)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_init(key, cfg, *, kind: Optional[str] = None) -> dict:
    kind = kind or cfg.mlp
    d, ff = cfg.d_model, cfg.d_ff
    if kind == "swiglu":
        kg, ku, kd = jax.random.split(key, 3)
        p = {"Wgate": winit(kg, (d, ff)), "Wup": winit(ku, (d, ff)),
             "Wdown": winit(kd, (ff, d))}
        for n, dout in (("Wgate", ff), ("Wup", ff), ("Wdown", d)):
            maybe_scale(p, n, cfg.quant, dout, jnp.float32)
    else:  # gelu
        k1, k2 = jax.random.split(key)
        p = {"Wfc1": winit(k1, (d, ff)), "Wfc2": winit(k2, (ff, d)),
             "bfc1": jnp.zeros((ff,)), "bfc2": jnp.zeros((d,))}
        for n, dout in (("Wfc1", ff), ("Wfc2", d)):
            maybe_scale(p, n, cfg.quant, dout, jnp.float32)
    return p


def mlp_apply(p: dict, x: Array, cfg) -> Array:
    if "Wgate" in p:
        g = scaled(qmatmul(x, p["Wgate"]), p, "Wgate", cfg.quant)
        u = scaled(qmatmul(x, p["Wup"]), p, "Wup", cfg.quant)
        h = jax.nn.silu(g) * u
        h = constrain(h, ("pod", "data"), None, "model")
        return scaled(qmatmul(h, p["Wdown"]), p, "Wdown", cfg.quant)
    h = jax.nn.gelu(scaled(qmatmul(x, p["Wfc1"]), p, "Wfc1", cfg.quant)
                    + p["bfc1"].astype(x.dtype))
    h = constrain(h, ("pod", "data"), None, "model")
    return scaled(qmatmul(h, p["Wfc2"]), p, "Wfc2", cfg.quant) + p["bfc2"].astype(x.dtype)
