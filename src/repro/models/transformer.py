"""Pattern-driven model covering the whole assigned pool.

One code path builds dense (llama3/qwen3/gemma3), MoE (mixtral/qwen3-moe),
SSM (rwkv6), hybrid (zamba2: mamba2 + shared attention block), enc-dec audio
(whisper backbone) and VLM (llama-3.2-vision: interleaved cross-attn) models
from a `ModelConfig`.

HLO-size discipline (critical for the 512-device dry-run): layers are grouped
by the repeating `block_pattern`; parameters of repeat r, pattern position i
are STACKED over r and the model runs as `lax.scan` over repeats with the
pattern unrolled inside the body.  A 100-layer model lowers to ~5 layer bodies
+ a scan, not 100 inlined layers.  KV caches / SSM states are stacked the same
way and streamed through the scan as xs/ys.

The paper's technique enters exactly once per step: `quantize_tree` maps
every QuantPolicy-matching leaf (stacked or not) through the stochastic
binary/ternary quantizer with straight-through gradients (core/qlinear.py).
At serving time the same forward functions accept an `export_packed` tree
whose weight leaves are packed `QTensor`s — `quantize_tree` passes them
through and every weight matmul dispatches via `kernels.ops.qmatmul`.
Everything else here is quantization-agnostic.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.qlinear import quantize_tree, winit
from repro.core.qtensor import QTensor
from repro.kernels.ops import qmatmul
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import moe as MOE
from repro.models import rwkv6 as R
from repro.runtime import constrain
from repro.serve.kvcache import (AttnCache, CrossCache, cache_init,
                                 cache_positions, cache_update)

Array = jax.Array

ATTN_KINDS = ("full", "global", "self", "local", "enc")
DECODE_MARGIN = 128  # extra cache slots beyond the spec'd context length


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def _remat_policy(cfg):
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return None


# ---------------------------------------------------------------------------
# pattern expansion
# ---------------------------------------------------------------------------


def expand_pattern(cfg) -> Tuple[Tuple[str, ...], int, Tuple[str, ...]]:
    """-> (pattern, repeats, tail_kinds).  n_layers counts *pattern* layers;
    for hybrids the shared-attn applications are extra (zamba2 style)."""
    if cfg.family == "hybrid" and cfg.attn_every > 0:
        pat = ("mamba",) * cfg.attn_every + ("shared",)
        rep = cfg.n_layers // cfg.attn_every
        tail = ("mamba",) * (cfg.n_layers % cfg.attn_every)
        return pat, rep, tail
    pat = cfg.block_pattern
    rep = cfg.n_layers // len(pat)
    tail = pat[: cfg.n_layers % len(pat)]
    return pat, rep, tail


def owns_params(kind: str) -> bool:
    return kind != "shared"


# ---------------------------------------------------------------------------
# per-block init
# ---------------------------------------------------------------------------


def block_init(key, cfg, kind: str) -> dict:
    d = cfg.d_model
    if kind == "mamba":
        k1, k2 = jax.random.split(key)
        return {"norm": jnp.zeros((d,)), "mixer": M.mamba2_init(k2, cfg)}
    if kind == "rwkv":
        k1, = jax.random.split(key, 1)
        return {"ln1": jnp.zeros((d,)), "ln2": jnp.zeros((d,)),
                "mix": R.rwkv6_init(k1, cfg)}
    ka, km = jax.random.split(key)
    p: dict = {"norm1": jnp.zeros((d,)), "norm2": jnp.zeros((d,))}
    if kind == "cross":
        p["attn"] = L.attn_init(ka, cfg, cross=True, kv_d=d)
    elif kind == "selfcross":
        kx, ka = jax.random.split(ka)
        p["attn"] = L.attn_init(ka, cfg)
        p["normc"] = jnp.zeros((d,))
        p["xattn"] = L.attn_init(kx, cfg, cross=True, kv_d=d)
    else:
        p["attn"] = L.attn_init(ka, cfg)
    if cfg.n_experts > 0 and kind not in ("enc",):
        p["moe"] = MOE.moe_init(km, cfg)
    else:
        p["mlp"] = L.mlp_init(km, cfg, kind="gelu" if cfg.family == "audio" else None)
    return p


def _stacked_init(key, cfg, kind: str, n: int) -> dict:
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: block_init(k, cfg, kind))(keys)


def model_init(key, cfg) -> dict:
    d, Vp = cfg.d_model, cfg.padded_vocab
    pat, rep, tail = expand_pattern(cfg)
    keys = jax.random.split(key, len(pat) + len(tail) + 8)
    ki = iter(range(len(keys)))

    params: dict = {
        "embed": jax.random.normal(keys[next(ki)], (Vp, d)) * (d ** -0.5),
        "final_norm": jnp.zeros((d,)),
    }
    if not cfg.tie_embeddings:
        params["head"] = winit(keys[next(ki)], (d, Vp))
    params["stack"] = tuple(
        _stacked_init(keys[next(ki)], cfg, k, rep) if owns_params(k) else {}
        for k in pat
    )
    params["tail"] = tuple(
        block_init(keys[next(ki)], cfg, k) if owns_params(k) else {} for k in tail
    )
    if cfg.family == "hybrid":
        params["shared"] = block_init(keys[next(ki)], cfg, "full")
    if cfg.family == "audio":
        ek = jax.random.split(keys[next(ki)], cfg.n_enc_layers + 1)
        params["enc"] = {
            "stack": _stacked_init(ek[0], cfg, "enc", cfg.n_enc_layers),
            "final_norm": jnp.zeros((d,)),
        }
    return params


# ---------------------------------------------------------------------------
# block apply — one function per (kind, cached?) path
# ---------------------------------------------------------------------------


def _mlp_or_moe(p: dict, x: Array, cfg, *, no_drop: bool = False) -> Tuple[Array, Array]:
    if "moe" in p:
        y, aux = MOE.moe_apply(p["moe"], x, cfg, no_drop=no_drop)
        return y, aux
    return L.mlp_apply(p["mlp"], x, cfg), jnp.zeros((), jnp.float32)


def _attn_full(p, x, cfg, kind, positions, xsrc):
    """Training/uncached attention block."""
    if cfg.parallel_block and kind in ("full", "global", "self", "local"):
        h = L.rms_norm(x, p["norm1"])
        o = L.attn_apply(p["attn"], h, cfg, kind=kind, positions=positions)
        y, aux = _mlp_or_moe(p, h, cfg)
        return x + o + y, aux
    h = L.rms_norm(x, p["norm1"])
    if kind == "cross":
        o = L.attn_apply(p["attn"], h, cfg, kind="cross", kv_src=xsrc)
    elif kind == "selfcross":
        o = L.attn_apply(p["attn"], h, cfg, kind="full", positions=positions)
        x = x + o
        hc = L.rms_norm(x, p["normc"])
        o = L.attn_apply(p["xattn"], hc, cfg, kind="cross", kv_src=xsrc)
    else:
        o = L.attn_apply(p["attn"], h, cfg, kind=kind, positions=positions,
                         causal=False if kind == "enc" else None)
    x = x + o
    h = L.rms_norm(x, p["norm2"])
    y, aux = _mlp_or_moe(p, h, cfg)
    return x + y, aux


def _self_attn_cached(p_attn, h, cfg, cache: AttnCache, *, window: int,
                      live=None):
    """h: (B, S, d) new tokens; attends over cache+new.  Returns (o, cache).
    `live` (B,) freezes dead continuous-batching rows' cache bytes/pos."""
    q = L.attn_q(p_attn, h, cfg)
    k_new, v_new = L.attn_kv(p_attn, h, cfg)
    S = h.shape[1]
    # scalar pos -> (S,); per-slot pos (B,) -> (B, S) (every slot of a
    # continuous-batching pool RoPEs/masks at its own sequence depth)
    ar = jnp.arange(S, dtype=jnp.int32)
    positions = cache.pos[:, None] + ar if cache.pos.ndim else cache.pos + ar
    q = L.rope(q, positions, cfg.rope_theta)
    k_new = L.rope(k_new, positions, cfg.rope_theta)
    cache = cache_update(cache, k_new, v_new, live)
    kv_pos = cache_positions(cache)
    # Match q's sharding to the cache policy: heads over 'model' only when
    # the KV heads themselves are head-sharded; with a LENGTH-sharded cache
    # (GQA kv-heads < TP degree) q stays replicated over 'model' so the
    # attention runs where the cache lives (partial logits + small gather)
    # instead of resharding gigabytes of cache every step.
    from repro.serve.kvcache import kv_pspec
    spec = kv_pspec(cache.k.shape[0], cache.k.shape[1], cache.k.shape[2])
    if len(spec) > 2 and spec[2] == "model":
        q = constrain(q, ("pod", "data"), None, "model", None)
    o = L.attention(q, cache.k, cache.v, causal=True, window=window,
                    q_offset=cache.pos - S, kv_pos=kv_pos,
                    chunk=cfg.attn_chunk, softcap=cfg.attn_softcap)
    return o, cache


def _attn_cached(p, x, cfg, kind, cache, xcache: Optional[CrossCache],
                 live=None):
    """Prefill/decode attention block; returns (x, new_cache, new_xcache)."""
    window = cfg.window if (kind == "local" or cfg.swa_all) else 0
    if cfg.parallel_block and kind in ("full", "global", "self", "local",
                                       "shared"):
        h = L.rms_norm(x, p["norm1"])
        o, cache = _self_attn_cached(p["attn"], h, cfg, cache, window=window,
                                     live=live)
        o = L.attn_out(p["attn"], o, cfg)
        y, aux = _mlp_or_moe(p, h, cfg, no_drop=x.shape[1] == 1)
        return x + o + y, cache, xcache, aux
    h = L.rms_norm(x, p["norm1"])
    if kind == "cross":
        q = L.attn_q(p["attn"], h, cfg)
        o = L.attention(q, xcache.k, xcache.v, causal=False)
        x = x + L.attn_out(p["attn"], o, cfg, cross=True)
    elif kind == "selfcross":
        o, cache = _self_attn_cached(p["attn"], h, cfg, cache, window=0,
                                     live=live)
        x = x + L.attn_out(p["attn"], o, cfg)
        hc = L.rms_norm(x, p["normc"])
        q = L.attn_q(p["xattn"], hc, cfg)
        o = L.attention(q, xcache.k, xcache.v, causal=False)
        x = x + L.attn_out(p["xattn"], o, cfg, cross=True)
    else:
        o, cache = _self_attn_cached(p["attn"], h, cfg, cache, window=window,
                                     live=live)
        x = x + L.attn_out(p["attn"], o, cfg)
    h = L.rms_norm(x, p["norm2"])
    y, aux = _mlp_or_moe(p, h, cfg, no_drop=x.shape[1] == 1)
    return x + y, cache, xcache, aux


def _mamba_block(p, x, cfg, state, decode):
    h = L.rms_norm(x, p["norm"])
    y, new_state = M.mamba2_apply(p["mixer"], h, cfg, state=state, decode=decode)
    return x + y.astype(x.dtype), new_state


def _rwkv_block(p, x, cfg, state: Optional[R.RWKVState], decode):
    h = L.rms_norm(x, p["ln1"])
    y, S, tm_last = R.rwkv6_time_mix(p["mix"], h, cfg, state=state, decode=decode)
    x = x + y.astype(x.dtype)
    h = L.rms_norm(x, p["ln2"])
    y, cm_last = R.rwkv6_channel_mix(
        p["mix"], h, cfg, prev=state.cm_shift if state is not None else None)
    x = x + y.astype(x.dtype)
    new_state = None
    if state is not None:
        new_state = R.RWKVState(S=S, tm_shift=tm_last, cm_shift=cm_last,
                                pos=state.pos + h.shape[1])
    return x, new_state


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------


def _kind_cache(cfg, kind: str, batch: int, cap: int, src_len: int, dtype,
                per_slot: bool = False):
    """Cache pytree for one layer of `kind` (python structure, zero arrays)."""
    Hkv, hd = cfg.n_kv, cfg.hd
    if kind in ("full", "global", "self", "shared"):
        c = cap if not cfg.swa_all else min(cfg.window + DECODE_MARGIN, cap)
        return {"attn": cache_init(batch, c, Hkv, hd, dtype, ring=cfg.swa_all,
                                   per_slot=per_slot)}
    if kind == "local":
        w = min(cfg.window + DECODE_MARGIN, cap)
        return {"attn": cache_init(batch, w, Hkv, hd, dtype, ring=True,
                                   per_slot=per_slot)}
    if kind == "cross":
        return {"cross": CrossCache(k=jnp.zeros((batch, src_len, Hkv, hd), dtype),
                                    v=jnp.zeros((batch, src_len, Hkv, hd), dtype))}
    if kind == "selfcross":
        return {"attn": cache_init(batch, cap, Hkv, hd, dtype,
                                   per_slot=per_slot),
                "cross": CrossCache(k=jnp.zeros((batch, src_len, Hkv, hd), dtype),
                                    v=jnp.zeros((batch, src_len, Hkv, hd), dtype))}
    if kind == "mamba":
        return {"ssm": M.state_init(cfg, batch, dtype, per_slot=per_slot)}
    if kind == "rwkv":
        return {"rwkv": R.state_init(cfg, batch, dtype, per_slot=per_slot)}
    raise ValueError(kind)


def init_caches(cfg, batch: int, context: int, *, src_len: int = 0,
                dtype=None, per_slot: bool = False) -> dict:
    """Stacked cache pytree matching the scan structure.  With `per_slot`
    every position counter is per-sequence (B,) so batch rows can sit at
    different depths — the continuous-batching pool layout (DESIGN.md §7)."""
    dtype = dtype or _dt(cfg)
    cap = context + DECODE_MARGIN
    pat, rep, tail = expand_pattern(cfg)

    def stack(kind):
        one = _kind_cache(cfg, kind, batch, cap, src_len, dtype, per_slot)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (rep,) + a.shape), one)

    return {
        "stack": tuple(stack(k) for k in pat),
        "tail": tuple(_kind_cache(cfg, k, batch, cap, src_len, dtype, per_slot)
                      for k in tail),
    }


# ---------------------------------------------------------------------------
# forward (training / eval — no caches)
# ---------------------------------------------------------------------------


def _embed(params, tokens: Array, cfg) -> Array:
    x = jnp.take(params["embed"], tokens, axis=0).astype(_dt(cfg))
    return constrain(x, ("pod", "data"), None, None)


def _head(params, x: Array, cfg) -> Array:
    if cfg.tie_embeddings:
        w = params["embed"].T.astype(x.dtype)  # embed stays fp (gather path)
    else:
        w = params["head"]
        w = w if isinstance(w, QTensor) else w.astype(x.dtype)
    logits = qmatmul(x, w).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab:
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab
        logits = jnp.where(pad_mask, logits, -1e30)
    return constrain(logits, ("pod", "data"), None, "model")


def _run_encoder(params, frames: Array, cfg) -> Array:
    """Whisper-style encoder over precomputed frame embeddings (stub frontend)."""
    x = frames.astype(_dt(cfg))
    x = x + L.sinusoidal_positions(frames.shape[1], cfg.d_model).astype(x.dtype)
    positions = jnp.arange(frames.shape[1], dtype=jnp.int32)

    def body(x, p_slice):
        y, _ = _attn_full(p_slice, x, cfg, "enc", positions, None)
        return y, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False, policy=_remat_policy(cfg))
    if cfg.n_enc_layers > 0 and cfg.unroll:
        for r in range(cfg.n_enc_layers):
            x, _ = body(x, jax.tree.map(lambda l: l[r], params["enc"]["stack"]))
    elif cfg.n_enc_layers > 0:
        x, _ = jax.lax.scan(body, x, params["enc"]["stack"])
    return L.rms_norm(x, params["enc"]["final_norm"])


def forward(params, tokens: Array, cfg, *, training: bool = False,
            rng: Optional[Array] = None, img: Optional[Array] = None,
            enc_frames: Optional[Array] = None,
            last_only: bool = False) -> Tuple[Array, Array]:
    """Full-sequence forward.  Returns (logits, moe_aux_loss).

    tokens: (B, S) int32.  img: (B, N_img, d) VLM patch embeddings (stub).
    enc_frames: (B, S_audio, d) whisper frame embeddings (stub).
    """
    spec = cfg.quant if training else dataclasses.replace(
        cfg.quant, stochastic=False)
    qparams = quantize_tree(params, spec, rng, compute_dtype=_dt(cfg))

    xsrc = None
    if cfg.family == "audio":
        xsrc = _run_encoder(qparams, enc_frames, cfg)
    elif cfg.family == "vlm":
        xsrc = img.astype(_dt(cfg))

    x = _embed(qparams, tokens, cfg)
    S = tokens.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    pat, rep, tail = expand_pattern(cfg)

    def apply_kind(p, x, kind):
        if kind == "mamba":
            y, _ = _mamba_block(p, x, cfg, None, False)
            return y, jnp.zeros((), jnp.float32)
        if kind == "rwkv":
            y, _ = _rwkv_block(p, x, cfg, None, False)
            return y, jnp.zeros((), jnp.float32)
        if kind == "shared":
            return _attn_full(qparams["shared"], x, cfg, "full", positions, xsrc)
        return _attn_full(p, x, cfg, kind, positions, xsrc)

    def body(carry, p_slices):
        x, aux = carry
        for kind, p in zip(pat, p_slices):
            x, a = apply_kind(p, x, kind)
            aux = aux + a
        x = constrain(x, ("pod", "data"), None, None)
        return (x, aux), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False, policy=_remat_policy(cfg))

    aux0 = jnp.zeros((), jnp.float32)
    if rep > 0 and cfg.unroll:
        carry = (x, aux0)
        for r in range(rep):
            carry, _ = body(carry, jax.tree.map(lambda l: l[r], qparams["stack"]))
        x, aux = carry
    elif rep > 0:
        (x, aux), _ = jax.lax.scan(body, (x, aux0), qparams["stack"])
    else:
        aux = aux0
    for kind, p in zip(tail, qparams["tail"]):
        x, a = apply_kind(p, x, kind)
        aux = aux + a

    x = L.rms_norm(x, qparams["final_norm"])
    if last_only:
        x = x[:, -1:]
    return _head(qparams, x, cfg), aux


# ---------------------------------------------------------------------------
# serving: prefill + decode share one cached-step implementation
# ---------------------------------------------------------------------------


def _freeze_dead(new, old, live):
    """Select per-row between a recurrent state update and the previous
    state: dead continuous-batching rows (live=False) keep every leaf —
    S-matrices, conv tails, shift buffers, pos — bit-for-bit.  The leaf's
    batch axis is axis 0 (RWKVState/SSMState are built per layer)."""
    def sel(n, o):
        m = live.reshape(live.shape + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)
    return jax.tree.map(sel, new, old)


def _step_cached(qparams, x, caches, cfg, *, decode: bool,
                 xsrc: Optional[Array], live=None) -> Tuple[Array, dict, Array]:
    """Run all layers over new tokens x (B,S,d) against caches.  `live`
    (B,) bool (decode tick of the continuous-batching engine) freezes dead
    rows' cache writes and recurrent states — a dead row may be a slot
    MID-PREFILL, whose state the zombie decode must not touch."""
    pat, rep, tail = expand_pattern(cfg)

    def apply_kind(p, x, kind, cache):
        aux0 = jnp.zeros((), jnp.float32)
        if kind == "mamba":
            y, st = _mamba_block(p, x, cfg, cache["ssm"], decode)
            if live is not None:
                st = _freeze_dead(st, cache["ssm"], live)
            return y, {"ssm": st}, aux0
        if kind == "rwkv":
            y, st = _rwkv_block(p, x, cfg, cache["rwkv"], decode)
            if live is not None:
                st = _freeze_dead(st, cache["rwkv"], live)
            return y, {"rwkv": st}, aux0
        pp = qparams["shared"] if kind == "shared" else p
        kk = "full" if kind == "shared" else kind
        xc = cache.get("cross")
        if xc is not None and not decode and xsrc is not None:
            # prefill: encode the cross source into the cache once
            name = "xattn" if kk == "selfcross" else "attn"
            k, v = L.attn_kv(pp[name], xsrc, cfg)
            xc = CrossCache(k=k, v=v)
        y, ac, xc, aux = _attn_cached(pp, x, cfg, kk, cache.get("attn"), xc,
                                      live)
        out = {}
        if ac is not None:
            out["attn"] = ac
        if xc is not None:
            out["cross"] = xc
        return y, out, aux

    def body(carry, xs):
        x, aux = carry
        p_slices, cache_slices = xs
        new_caches = []
        for kind, p, c in zip(pat, p_slices, cache_slices):
            x, nc, a = apply_kind(p, x, kind, c)
            new_caches.append(nc)
            aux = aux + a
        x = constrain(x, ("pod", "data"), None, None)
        return (x, aux), tuple(new_caches)

    aux0 = jnp.zeros((), jnp.float32)
    if rep > 0 and cfg.unroll:
        carry = (x, aux0)
        outs = []
        for r in range(rep):
            sl = lambda t: jax.tree.map(lambda l: l[r], t)
            carry, nc = body(carry, (sl(qparams["stack"]), sl(caches["stack"])))
            outs.append(nc)
        (x, aux) = carry
        new_stack = jax.tree.map(lambda *ls: jnp.stack(ls), *outs) if outs else \
            caches["stack"]
    elif rep > 0:
        (x, aux), new_stack = jax.lax.scan(
            body, (x, aux0), (qparams["stack"], caches["stack"]))
    else:
        aux, new_stack = aux0, caches["stack"]
    new_tail = []
    for kind, p, c in zip(tail, qparams["tail"], caches["tail"]):
        x, nc, a = apply_kind(p, x, kind, c)
        new_tail.append(nc)
        aux = aux + a
    return x, {"stack": new_stack, "tail": tuple(new_tail)}, aux


def _serve_quant(params, cfg):
    spec = dataclasses.replace(cfg.quant, stochastic=False)
    return quantize_tree(params, spec, None, compute_dtype=_dt(cfg))


def _rewind_pad(caches: dict, pad) -> dict:
    """Drop `pad` bucket-padding tokens back off every attention cache's
    per-slot pos.  The pad tokens' k/v bytes stay where they were written,
    but `cache_positions` derives validity from pos alone, so they read as
    unwritten and the next chunk / decode step overwrites them.  Only
    meaningful for runtimes whose caches are pure attention (the engine
    gates bucket padding on that)."""
    is_c = lambda c: isinstance(c, AttnCache)
    return jax.tree.map(lambda c: c._replace(pos=c.pos - pad) if is_c(c) else c,
                        caches, is_leaf=is_c)


def prefill(params, tokens: Array, caches: dict, cfg, *,
            img: Optional[Array] = None,
            enc_frames: Optional[Array] = None,
            n: Optional[Array] = None) -> Tuple[Array, dict]:
    """Process the prompt, fill caches.  Returns (last-token logits, caches).

    `n` (traced int32) marks the first n of tokens as real and the tail as
    bucket padding: the returned logits are taken at position n-1 and the
    attention caches' pos is rewound by the pad count, so a fixed bucket
    length serves every real chunk length with one jit trace (chunked
    in-slot prefill, DESIGN.md §8)."""
    qparams = _serve_quant(params, cfg)
    xsrc = None
    if cfg.family == "audio":
        xsrc = _run_encoder(qparams, enc_frames, cfg)
    elif cfg.family == "vlm":
        xsrc = img.astype(_dt(cfg))
    x = _embed(qparams, tokens, cfg)
    x, caches, _ = _step_cached(qparams, x, caches, cfg, decode=False, xsrc=xsrc)
    if n is None:
        x = x[:, -1:]
    else:
        n = jnp.asarray(n, jnp.int32)
        x = jax.lax.dynamic_slice_in_dim(x, n - 1, 1, axis=1)
        caches = _rewind_pad(caches, tokens.shape[1] - n)
    x = L.rms_norm(x, qparams["final_norm"])
    return _head(qparams, x, cfg)[:, 0], caches


def verify_step(params, tokens: Array, caches: dict, cfg,
                live: Optional[Array] = None) -> Tuple[Array, dict]:
    """Speculative-decoding verify: one multi-token decode over the
    candidate span.  tokens: (B, S) int32 with S = spec_k + 1 (static) ->
    (logits (B, S, Vp), caches).

    Unlike `prefill` this returns logits at EVERY position — the
    acceptance rule needs the target distribution at each candidate — and
    each position's head runs at the decode step's (B, 1, d) shape
    (unrolled: S is a small static constant), because matmul rounding
    depends on the row count and the verified stream must be bit-identical
    to plain decoding at temperature 0.  `live` (B,) freezes dead rows'
    cache bytes/pos exactly as in the decode tick; rollback of rejected
    suffixes is the caller's job (kvcache.cache_spec_commit)."""
    qparams = _serve_quant(params, cfg)
    x = _embed(qparams, tokens, cfg)
    x, caches, _ = _step_cached(qparams, x, caches, cfg, decode=True,
                                xsrc=None, live=live)
    x = L.rms_norm(x, qparams["final_norm"])
    logits = [_head(qparams, x[:, i:i + 1], cfg)[:, 0]
              for i in range(tokens.shape[1])]
    return jnp.stack(logits, axis=1), caches


def decode_step(params, token: Array, caches: dict, cfg,
                live: Optional[Array] = None) -> Tuple[Array, dict]:
    """One decode step.  token: (B,) or (B,1) int32 -> (logits (B, Vp), caches).

    `live` (B,) bool is the continuous-batching engine's occupancy mask:
    dead rows' caches and recurrent states stay bit-for-bit frozen (their
    logits are garbage and never sampled)."""
    if token.ndim == 1:
        token = token[:, None]
    qparams = _serve_quant(params, cfg)
    x = _embed(qparams, token, cfg)
    x, caches, _ = _step_cached(qparams, x, caches, cfg, decode=True,
                                xsrc=None, live=live)
    x = L.rms_norm(x, qparams["final_norm"])
    return _head(qparams, x, cfg)[:, 0], caches


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def lm_loss(params, batch: dict, cfg, *, training: bool = True,
            rng: Optional[Array] = None, aux_weight: float = 0.01,
            z_weight: float = 1e-4):
    """batch: {'tokens': (B,S), 'targets': (B,S), optional 'img'/'enc_frames'}."""
    logits, aux = forward(params, batch["tokens"], cfg, training=training,
                          rng=rng, img=batch.get("img"),
                          enc_frames=batch.get("enc_frames"))
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, batch["targets"][..., None], axis=-1)[..., 0]
    nll = jnp.mean(logz - tgt)
    loss = nll + aux_weight * aux + z_weight * jnp.mean(jnp.square(logz))
    return loss, {"nll": nll, "moe_aux": aux}
