"""Unbiased stochastic ternary gradient compression (TernGrad-style) — a
beyond-paper extension reusing the paper's own Eq.(5/6) machinery on
GRADIENTS: each DP replica ternarizes its local gradient before the cross-
replica reduction, cutting all-reduce bytes ~16x (2-bit codes + one fp scale
per tensor).

  t = s * Tern(g / s),  s = max|g|  (per tensor)   =>   E[t] = g  (unbiased)

Error feedback (Seide et al.) keeps the quantization residual local and adds
it to the next step's gradient, which empirically removes the convergence
penalty.  Used inside `shard_map` (train_step.py) where per-replica gradients
exist before the psum.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def ternary_compress(g: Array, key: Array) -> Tuple[Array, Array]:
    """-> (t, scale) with t in {-1,0,+1}*scale and E[t] = g."""
    scale = jnp.max(jnp.abs(g)) + 1e-12
    p = jnp.abs(g) / scale
    u = jax.random.uniform(key, g.shape, jnp.float32)
    t = jnp.where(u < p, jnp.sign(g), 0.0).astype(g.dtype)
    return t * scale, scale


def compress_tree(grads: Any, key: Array,
                  residual: Optional[Any] = None) -> Tuple[Any, Any]:
    """Ternarize every leaf (with error feedback when `residual` given).
    Returns (compressed_grads, new_residual)."""
    leaves, treedef = jax.tree.flatten(grads)
    res_leaves = jax.tree.leaves(residual) if residual is not None else [
        jnp.zeros_like(l) for l in leaves]
    keys = jax.random.split(key, len(leaves))
    out, new_res = [], []
    for leaf, r, k in zip(leaves, res_leaves, keys):
        corrected = leaf + r
        t, _ = ternary_compress(corrected, k)
        out.append(t)
        new_res.append(corrected - t)
    return treedef.unflatten(out), treedef.unflatten(new_res)


def compressed_bytes(grads: Any) -> tuple[int, int]:
    """(fp32 all-reduce bytes, 2-bit-code all-reduce bytes) for reporting."""
    n = sum(x.size for x in jax.tree.leaves(grads))
    n_tensors = len(jax.tree.leaves(grads))
    return 4 * n, (2 * n) // 8 + 4 * n_tensors
