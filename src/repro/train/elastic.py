"""Elastic scaling: recompute the best mesh from surviving devices.

When hosts are evicted (failure / straggler), the controller restarts the job
on the survivors.  `best_mesh_shape` picks the largest usable (pod, data,
model) factorization that (a) preserves the model axis when possible —
parameter shards must still fit — and (b) keeps the global batch divisible so
optimizer semantics don't change (per-replica batch is rescaled instead).
Checkpoints are resharding-agnostic (full-tensor leaves on this container's
single host; per-shard layout carries index metadata on real fleets).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    per_replica_batch: int
    dropped_devices: int


def _divisors_desc(n: int):
    return [d for d in range(n, 0, -1) if n % d == 0]


def best_mesh_shape(n_devices: int, *, want_model: int, global_batch: int,
                    pods: int = 1, min_util: float = 0.9) -> MeshPlan:
    """Largest feasible (pod, data, model) using <= n_devices.

    First pass keeps the global batch EXACTLY divisible (identical optimizer
    semantics).  If that wastes more than (1 - min_util) of the fleet, a
    second pass takes the largest mesh and rescales the per-replica batch to
    the nearest value (global batch changes by < one replica batch — the
    standard elastic-training compromise, logged by the caller)."""
    def plan(data, model):
        used = pods * data * model
        shape = (pods, data, model) if pods > 1 else (data, model)
        axes = ("pod", "data", "model") if pods > 1 else ("data", "model")
        prb = max(1, round(global_batch / (pods * data)))
        return MeshPlan(shape=shape, axes=axes, per_replica_batch=prb,
                        dropped_devices=n_devices - used)

    best_exact = None
    for model in [want_model] + _divisors_desc(want_model)[1:]:
        data = (n_devices // pods) // model
        while data > 0:
            if global_batch % (pods * data) == 0:
                p = plan(data, model)
                if best_exact is None or p.dropped_devices < best_exact.dropped_devices:
                    best_exact = p
                break
            data -= 1
    if best_exact is not None and \
            best_exact.dropped_devices <= (1 - min_util) * n_devices:
        return best_exact
    # utilization-first fallback: largest mesh, batch rescaled
    for model in [want_model] + _divisors_desc(want_model)[1:]:
        data = (n_devices // pods) // model
        if data > 0:
            return plan(data, model)
    if best_exact is not None:
        return best_exact
    raise ValueError(f"no feasible mesh for {n_devices} devices, "
                     f"batch {global_batch}")


def make_mesh_from_plan(plan: MeshPlan, devices=None) -> jax.sharding.Mesh:
    devices = devices if devices is not None else jax.devices()
    n = 1
    for s in plan.shape:
        n *= s
    import numpy as np
    return jax.sharding.Mesh(
        np.asarray(devices[:n]).reshape(plan.shape), plan.axes)
