"""Hand-rolled functional optimizers (no optax in the container).

AdamW (the paper trains char-LM/MNIST/QA with ADAM) and SGD with gradient
clipping + the /4-on-plateau schedule the paper uses for word-PTB.  The
update pipeline ends with the paper's master-weight clip to [-alpha, alpha]
(core.qlinear.clip_tree) so Bernoulli probabilities stay valid — that clip is
part of the algorithm, not a generic optimizer knob.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"          # adamw | sgd
    lr: float = 2e-3             # paper: 0.002 for char-LM
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    momentum: float = 0.0        # SGD momentum buffer coefficient (0 = plain)
    clip_norm: float = 0.0       # 0 = off; paper word-PTB: 0.25
    warmup_steps: int = 0
    decay_steps: int = 0         # cosine horizon; 0 = constant
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: Array
    m: Any
    v: Any


def opt_init(params: Any, cfg: OptConfig) -> OptState:
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    if cfg.kind == "adamw":
        return OptState(step=jnp.zeros((), jnp.int32), m=zeros(params), v=zeros(params))
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros(params), v=None)


def schedule(step: Array, cfg: OptConfig) -> Array:
    lr = jnp.asarray(cfg.lr, jnp.float32)
    s = step.astype(jnp.float32)
    if cfg.warmup_steps > 0:
        lr = lr * jnp.minimum(1.0, (s + 1.0) / cfg.warmup_steps)
    if cfg.decay_steps > 0:
        t = jnp.clip((s - cfg.warmup_steps) / max(cfg.decay_steps - cfg.warmup_steps, 1),
                     0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        lr = lr * (cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos)
    return lr


def global_norm(tree: Any) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, Array]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def opt_update(grads: Any, state: OptState, params: Any, cfg: OptConfig,
               lr_scale: Array | float = 1.0) -> tuple[Any, OptState, dict]:
    """Returns (new_params, new_state, metrics)."""
    metrics = {}
    if cfg.clip_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    metrics["grad_norm"] = gnorm

    step = state.step + 1
    lr = schedule(state.step, cfg) * lr_scale
    metrics["lr"] = lr

    if cfg.kind == "adamw":
        b1, b2 = cfg.b1, cfg.b2
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state.m, grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g),
                         state.v, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, mm, vv):
            u = (mm / bc1) / (jnp.sqrt(vv / bc2) + cfg.eps)
            if cfg.weight_decay > 0:
                u = u + cfg.weight_decay * p
            return p - lr * u

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, OptState(step=step, m=m, v=v), metrics

    # SGD with momentum buffer in m (paper's word-PTB setting uses plain SGD,
    # i.e. the default momentum=0.0; the buffer only carries when asked to)
    mom = cfg.momentum
    m = jax.tree.map(lambda mm, g: mom * mm + g, state.m, grads)
    new_params = jax.tree.map(lambda p, mm: p - lr * mm, params, m)
    return new_params, OptState(step=step, m=m, v=None), metrics


class PlateauLR:
    """Host-side plateau schedule (paper word-PTB: divide LR by 4 whenever
    validation perplexity rises *versus the previous evaluation*).  Produces
    an `lr_scale` fed to opt_update.

    The comparison is against the PREVIOUS eval, not the all-time best:
    comparing against the best would multiply `scale` by `factor` on every
    eval of a normal noisy recovery (each one still above the old best) and
    collapse the LR geometrically after a single rise.  `best` is still
    tracked, but only for reporting."""

    def __init__(self, factor: float = 0.25):
        self.factor = factor
        self.prev: Optional[float] = None
        self.best: Optional[float] = None
        self.scale = 1.0

    def update(self, val_metric: float) -> float:
        if self.prev is not None and val_metric > self.prev:
            self.scale *= self.factor
        self.prev = val_metric
        if self.best is None or val_metric < self.best:
            self.best = val_metric
        return self.scale

    def replay(self, val_metrics) -> float:
        """Rebuild schedule state from a recorded metric history (restart
        path: the launcher journals every eval, so a resumed run re-derives
        the exact lr_scale the interrupted run was using)."""
        for v in val_metrics:
            self.update(float(v))
        return self.scale
