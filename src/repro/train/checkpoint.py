"""Atomic, sharded-aware, optionally-async checkpointing (no orbax on box).

Layout:  <dir>/step_<n>/
             manifest.json        tree structure, shapes, dtypes, step
             <leafpath>.npy       one file per leaf (process-local shards on
                                  multi-host: each process writes the leaves
                                  it owns under shard_<pid>/)

Atomicity: everything is written into `step_<n>.tmp-<nonce>` and os.replace'd
into place last, so a preemption mid-write never corrupts the latest
checkpoint.  `latest_step` only believes directories containing a manifest.

Async: `save_async` snapshots to host memory synchronously (cheap: device ->
pinned host copy) and runs the file I/O on a worker thread, overlapping the
next training steps; `wait()` joins before the next save or exit.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import uuid
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from repro.core.qtensor import QTensor, is_qtensor

SEP = "."


def _key_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def _flatten(tree: Any) -> dict:
    """Flatten to {dotted-path: array}.  QTensor nodes flatten through their
    registered pytree structure, so an exported packed tree checkpoints as
    `<leaf>.codes.npy` (+ `<leaf>.scale.npy` when present) with the static
    k/mode/alpha metadata recorded separately (see `_qtensor_meta`)."""
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[SEP.join(_key_str(p) for p in path)] = leaf
    return flat


def _qtensor_meta(tree: Any) -> dict:
    """{dotted-path: {k, mode, alpha}} for every QTensor node in the tree."""
    meta = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            tree, is_leaf=is_qtensor)[0]:
        if is_qtensor(leaf):
            key = SEP.join(_key_str(p) for p in path)
            meta[key] = {"k": leaf.k, "mode": leaf.mode, "alpha": leaf.alpha}
    return meta


def save(tree: Any, directory: str | Path, step: int,
         *, process_id: int = 0, keep: int = 3) -> Path:
    """Synchronous atomic save.  Returns the final checkpoint path."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp-{uuid.uuid4().hex[:8]}"
    shard_dir = tmp / f"shard_{process_id:05d}"
    shard_dir.mkdir(parents=True, exist_ok=True)

    flat = _flatten(tree)
    manifest = {"step": step, "leaves": {}, "treedef_keys": sorted(flat),
                "qtensors": _qtensor_meta(tree)}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        np.save(shard_dir / f"{key}.npy", arr)
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    _gc(directory, keep)
    return final


def _gc(directory: Path, keep: int):
    done = sorted(p for p in directory.glob("step_*") if
                  (p / "manifest.json").exists())
    for p in done[:-keep]:
        shutil.rmtree(p, ignore_errors=True)
    for p in directory.glob("step_*.tmp-*"):  # orphaned partial writes
        shutil.rmtree(p, ignore_errors=True)


def latest_step(directory: str | Path) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in directory.glob("step_*")
             if (p / "manifest.json").exists()]
    return max(steps) if steps else None


def restore(template: Any, directory: str | Path, step: Optional[int] = None,
            *, process_id: int = 0) -> Any:
    """Restore into the structure of `template` (shapes must match)."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    ckpt = directory / f"step_{step:08d}"
    shard_dir = ckpt / f"shard_{process_id:05d}"
    # validate QTensor metadata: a packed checkpoint only restores into a
    # template packed the same way (same k / mode / alpha — alpha is
    # normally derived from the shape, so a drift there is a real
    # corruption signal, and custom alphas must survive the round trip).
    manifest = json.loads((ckpt / "manifest.json").read_text())
    saved_q = manifest.get("qtensors", {})
    for key, meta in _qtensor_meta(template).items():
        got = saved_q.get(key)
        if got is not None and (got["k"] != meta["k"]
                                or got["mode"] != meta["mode"]
                                or abs(got["alpha"] - meta["alpha"]) > 1e-9):
            raise ValueError(
                f"{key}: checkpoint QTensor (k={got['k']}, mode={got['mode']},"
                f" alpha={got['alpha']}) != template (k={meta['k']}, "
                f"mode={meta['mode']}, alpha={meta['alpha']})")
    flat_t = _flatten(template)
    loaded = {}
    for key, leaf in flat_t.items():
        arr = np.load(shard_dir / f"{key}.npy")
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"template {leaf.shape}")
        loaded[key] = arr

    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    keys = [SEP.join(_key_str(p) for p in path) for path, _ in paths]
    return jax.tree_util.tree_unflatten(treedef, [loaded[k] for k in keys])


class AsyncCheckpointer:
    def __init__(self, directory: str | Path, *, keep: int = 3,
                 process_id: int = 0):
        self.directory = Path(directory)
        self.keep = keep
        self.process_id = process_id
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save_async(self, tree: Any, step: int):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save(host_tree, self.directory, step,
                     process_id=self.process_id, keep=self.keep)
            except BaseException as e:  # re-raised on wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
