"""Fault tolerance: preemption handling + straggler detection.

PreemptionHandler — converts SIGTERM/SIGINT into a cooperative "checkpoint
now and exit 43" request; the launcher (launch/train.py) treats exit code 43
as "restart me" (the standard TPU-preemption contract).

StragglerMonitor — EWMA of per-host step time vs the fleet median; hosts
persistently above `ratio` are flagged so the controller can evict them and
trigger an elastic reshape (train/elastic.py).  On a real multi-host fleet
the per-host timings arrive through a tiny all-gather each N steps; the
aggregation logic here is host-side and identical.
"""
from __future__ import annotations

import signal
import threading
import time
from typing import Dict, List, Optional

RESTART_EXIT_CODE = 43


class PreemptionHandler:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._flag = threading.Event()
        self._orig = {}
        for s in signals:
            try:
                self._orig[s] = signal.signal(s, self._handler)
            except ValueError:  # not main thread (tests)
                pass

    def _handler(self, signum, frame):
        self._flag.set()

    @property
    def preempted(self) -> bool:
        return self._flag.is_set()

    def simulate(self):  # for tests / chaos drills
        self._flag.set()

    def restore(self):
        for s, h in self._orig.items():
            signal.signal(s, h)


class StragglerMonitor:
    """Track per-host EWMA step times; flag hosts slower than ratio x median."""

    def __init__(self, n_hosts: int, alpha: float = 0.1, ratio: float = 1.5,
                 patience: int = 3):
        self.ewma: Dict[int, float] = {}
        self.strikes: Dict[int, int] = {h: 0 for h in range(n_hosts)}
        self.alpha = alpha
        self.ratio = ratio
        self.patience = patience

    def record(self, host: int, dt: float):
        prev = self.ewma.get(host)
        self.ewma[host] = dt if prev is None else (
            (1 - self.alpha) * prev + self.alpha * dt)

    def record_all(self, dts: Dict[int, float]) -> List[int]:
        for h, dt in dts.items():
            self.record(h, dt)
        return self.flagged()

    def flagged(self) -> List[int]:
        if len(self.ewma) < 2:
            return []
        vals = sorted(self.ewma.values())
        median = vals[len(vals) // 2]
        out = []
        for h, v in self.ewma.items():
            if v > self.ratio * median:
                self.strikes[h] = self.strikes.get(h, 0) + 1
            else:
                self.strikes[h] = 0
            if self.strikes.get(h, 0) >= self.patience:
                out.append(h)
        return out


class StepTimer:
    def __init__(self):
        self.t0: Optional[float] = None

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0
        return False
