"""Train-step factories for the transformer pool and the paper's RNNs.

Both return pure jit/pjit-compatible functions over an explicit TrainState
pytree.  The update pipeline is:

    grads -> [optional ternary compression + DP all-reduce via shard_map]
          -> clip -> AdamW/SGD -> master-weight clip to [-alpha, alpha]

The final clip is the paper's algorithm (keeps Bernoulli probabilities in
[0,1]); it is applied only to quantizable 'W*' leaves.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import bnlstm as BL
from repro.core.qlinear import clip_tree
from repro.models import transformer as T
from repro.train import compress as C
from repro.train.optimizer import OptConfig, OptState, opt_init, opt_update

Array = jax.Array


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    rng: Array
    # RNN-only: batch-norm running statistics (None for transformers)
    bn_state: Any = None
    # gradient-compression error-feedback residual (None = compression off)
    residual: Any = None


def train_state_init(params: Any, opt_cfg: OptConfig, rng: Array,
                     bn_state: Any = None, compress: bool = False) -> TrainState:
    return TrainState(
        params=params,
        opt=opt_init(params, opt_cfg),
        rng=rng,
        bn_state=bn_state,
        residual=jax.tree.map(jnp.zeros_like, params) if compress else None,
    )


# ---------------------------------------------------------------------------
# compressed-DP key hygiene (shared by both families)
# ---------------------------------------------------------------------------


def _dp_step_keys(rng: Array, data_axes) -> tuple[Array, Array]:
    """Split one per-step key into (model key, compression key) inside a
    shard_map region.

    Two distinct hazards, two distinct fixes: (1) the model key (dropout /
    stochastic quantization inside loss_fn) must be a DIFFERENT key from the
    one driving `compress_tree`'s stochastic ternarization, or the two
    random processes are correlated; (2) the rng arrives REPLICATED (in_spec
    P()), so without decorrelation every data replica draws identical
    compression randomness — correlated quantization noise that the
    cross-replica mean cannot average away, defeating the error-feedback
    variance reduction.  Folding each data axis' `axis_index` into the
    compression key gives every replica an independent stream while the
    model key stays replicated (matching the unsharded path's semantics of
    one global-batch dropout draw per step)."""
    k_model, k_comp = jax.random.split(rng)
    for ax in data_axes:
        k_comp = jax.random.fold_in(k_comp, jax.lax.axis_index(ax))
    return k_model, k_comp


# ---------------------------------------------------------------------------
# transformer pool
# ---------------------------------------------------------------------------


def make_train_step(cfg, opt_cfg: OptConfig,
                    mesh=None, compress_grads: bool = False) -> Callable:
    """Returns step(state, batch) -> (state, metrics).

    With `compress_grads` and a mesh, per-replica gradients are ternarized
    (error feedback) inside shard_map before the data-parallel mean — the
    all-reduce then moves 2-bit codes instead of fp32 (DESIGN.md §4).
    """

    def loss_fn(params, batch, rng):
        return T.lm_loss(params, batch, cfg, training=True, rng=rng)

    def apply_updates(state: TrainState, grads, metrics):
        params, opt, m2 = opt_update(grads, state.opt, state.params, opt_cfg)
        params = clip_tree(params, cfg.quant)
        metrics.update(m2)
        return params, opt, metrics

    if not (compress_grads and mesh is not None):
        def step(state: TrainState, batch) -> tuple[TrainState, dict]:
            rng, sub = jax.random.split(state.rng)
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state.params, batch, sub)
            metrics = {"loss": loss, **aux}
            params, opt, metrics = apply_updates(state, grads, metrics)
            return state._replace(params=params, opt=opt, rng=rng), metrics

        return step

    # Compressed-DP variant: local grads inside shard_map over the data axes.
    # This path is pure data parallelism (each replica holds full params and
    # ternarizes its local gradient before the cross-replica mean); combine
    # with TP by nesting meshes at the launcher level.
    from jax.experimental.shard_map import shard_map
    from repro.runtime import use_mesh

    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    bspec = P(data_axes if len(data_axes) > 1 else data_axes[0])
    rep = P()

    def local_grads(params, batch, rng, residual):
        k_model, k_comp = _dp_step_keys(rng, data_axes)
        # inside shard_map the mesh axes are Manual: the model's internal
        # with_sharding_constraint calls must become no-ops
        with use_mesh(None):
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch, k_model)
        grads, new_res = C.compress_tree(grads, k_comp, residual)
        mean = lambda t: jax.tree.map(
            lambda x: jax.lax.pmean(x, data_axes), t)
        # the residual is pmean'd too: per-replica randomness makes the raw
        # residuals genuinely diverge, and the carried TrainState.residual is
        # replicated (out_spec P()).  The mean residual preserves the exact
        # aggregate conservation law — mean(emitted) + mean(new_res) ==
        # mean(grads) + old_res — so no signal is lost across steps.
        return mean(grads), mean(new_res), mean(loss), mean(aux["nll"])

    def step(state: TrainState, batch) -> tuple[TrainState, dict]:
        rng, sub = jax.random.split(state.rng)
        batch_specs = jax.tree.map(
            lambda x: P(bspec[0], *([None] * (x.ndim - 1))), batch)
        fn = shard_map(
            local_grads, mesh=mesh,
            in_specs=(rep, batch_specs, rep, rep),
            out_specs=(rep, rep, rep, rep),
            check_rep=False)
        grads, new_res, loss, nll = fn(state.params, batch, sub, state.residual)
        metrics = {"loss": loss, "nll": nll}
        params, opt, metrics = apply_updates(state, grads, metrics)
        return state._replace(params=params, opt=opt, rng=rng,
                              residual=new_res), metrics

    return step


# ---------------------------------------------------------------------------
# the paper's BN-LSTM / BN-GRU
# ---------------------------------------------------------------------------


def make_rnn_train_step(cfg: BL.RNNConfig, opt_cfg: OptConfig,
                        mesh=None, compress_grads: bool = False) -> Callable:
    """step(state, batch, lr_scale=1.0) -> (state, metrics) for the faithful
    reproduction.  Threads BN running statistics through the state (paper
    Eq. 3).  `lr_scale` (traced scalar) is the plateau-schedule hook: the
    launcher feeds `PlateauLR.update(val_bpc)` through it without retracing.

    With `compress_grads` and a mesh, the same ternary-compressed
    data-parallel pipeline as the transformer pool runs on the paper's own
    model: per-replica gradients are ternarized (error feedback) inside
    shard_map before the cross-replica mean.  BN batch statistics are then
    per-replica (local-batch BN) with the running stats pmean'd — the
    standard sync-free recurrent-BN compromise; the uncompressed path keeps
    exact global-batch statistics."""

    def loss_fn(params, bn_state, tokens, targets, rng):
        loss, new_bn = BL.lm_loss({"params": params, "state": bn_state},
                                  tokens, targets, cfg, training=True, rng=rng)
        return loss, new_bn

    def apply_updates(state: TrainState, grads, lr_scale):
        params, opt, m2 = opt_update(grads, state.opt, state.params, opt_cfg,
                                     lr_scale)
        return BL.clip_masters(params, cfg), opt, m2

    if not (compress_grads and mesh is not None):
        def step(state: TrainState, batch,
                 lr_scale: Array | float = 1.0) -> tuple[TrainState, dict]:
            rng, sub = jax.random.split(state.rng)
            (loss, new_bn), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state.params, state.bn_state,
                batch["tokens"], batch["targets"], sub)
            metrics = {"loss": loss, "bpc": loss / jnp.log(2.0)}
            params, opt, m2 = apply_updates(state, grads, lr_scale)
            metrics.update(m2)
            return state._replace(params=params, opt=opt, rng=rng,
                                  bn_state=new_bn), metrics

        return step

    from jax.experimental.shard_map import shard_map
    from repro.runtime import use_mesh

    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    bspec = P(data_axes if len(data_axes) > 1 else data_axes[0])
    rep = P()

    def local_grads(params, bn_state, tokens, targets, rng, residual):
        k_model, k_comp = _dp_step_keys(rng, data_axes)
        with use_mesh(None):
            (loss, new_bn), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, bn_state, tokens, targets, k_model)
        grads, new_res = C.compress_tree(grads, k_comp, residual)
        mean = lambda t: jax.tree.map(
            lambda x: jax.lax.pmean(x, data_axes), t)
        return mean(grads), mean(new_res), mean(loss), mean(new_bn)

    def step(state: TrainState, batch,
             lr_scale: Array | float = 1.0) -> tuple[TrainState, dict]:
        rng, sub = jax.random.split(state.rng)
        tspec = P(bspec[0], None)
        fn = shard_map(
            local_grads, mesh=mesh,
            in_specs=(rep, rep, tspec, tspec, rep, rep),
            out_specs=(rep, rep, rep, rep),
            check_rep=False)
        grads, new_res, loss, new_bn = fn(
            state.params, state.bn_state, batch["tokens"], batch["targets"],
            sub, state.residual)
        metrics = {"loss": loss, "bpc": loss / jnp.log(2.0)}
        params, opt, m2 = apply_updates(state, grads, lr_scale)
        metrics.update(m2)
        return state._replace(params=params, opt=opt, rng=rng,
                              bn_state=new_bn, residual=new_res), metrics

    return step


def make_rnn_eval(cfg: BL.RNNConfig) -> Callable:
    def evaluate(state: TrainState, batch) -> dict:
        loss, _ = BL.lm_loss({"params": state.params, "state": state.bn_state},
                             batch["tokens"], batch["targets"], cfg,
                             training=False)
        return {"loss": loss, "bpc": loss / jnp.log(2.0)}

    return evaluate
