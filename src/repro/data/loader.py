"""Device feeding: sharded placement + double-buffered host->device prefetch.

`shard_batch` places a host batch with the batch axis sharded over the data
axes of the current mesh.  `Prefetcher` overlaps the host-side batch
assembly and H2D copy of step k+1..k+depth with the device compute of step k
(one of the DESIGN.md distributed-optimization items)."""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_sharding(mesh: Optional[Mesh]) -> Optional[NamedSharding]:
    if mesh is None:
        return None
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return NamedSharding(mesh, P(axes if len(axes) > 1 else (axes[0] if axes else None)))


def shard_batch(batch: dict, mesh: Optional[Mesh]) -> dict:
    sh = batch_sharding(mesh)

    def put(x):
        if sh is None:
            return jax.device_put(x)
        spec = P(sh.spec[0], *([None] * (np.ndim(x) - 1)))
        return jax.device_put(x, NamedSharding(mesh, spec))

    return {k: put(v) for k, v in batch.items()}


class Prefetcher:
    """Pulls batches from `make_batch(step)` on a worker thread, `depth`
    steps ahead, placing them on device.  Stateless upstream (step-indexed)
    means dropping the queue on restart loses nothing."""

    def __init__(self, make_batch: Callable[[int], dict], start_step: int,
                 mesh: Optional[Mesh] = None, depth: int = 2):
        self.make_batch = make_batch
        self.mesh = mesh
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            try:
                b = shard_batch(self.make_batch(step), self.mesh)
            except Exception as e:  # surface errors on the consumer side
                self.q.put(e)
                return
            self.q.put((step, b))
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        item = self.q.get()
        if isinstance(item, Exception):
            raise item
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
