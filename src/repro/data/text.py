"""Byte/char-level corpus pipeline from local files.

Stateless by construction: every batch is a pure function of (split, step),
so a restarted job resumes exactly (fault-tolerance requirement — no iterator
state in checkpoints).  Window sampling uses a counter-based hash, giving a
reshuffled epoch without materializing permutations.
"""
from __future__ import annotations

import dataclasses
import hashlib
from pathlib import Path
from typing import Iterable, Sequence

import numpy as np


def _mix64(x: np.ndarray) -> np.ndarray:
    """SplitMix64 — counter-based pseudo-random positions."""
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    x ^= x >> np.uint64(30)
    x = (x * np.uint64(0xBF58476D1CE4E5B9)).astype(np.uint64)
    x ^= x >> np.uint64(27)
    x = (x * np.uint64(0x94D049BB133111EB)).astype(np.uint64)
    return x ^ (x >> np.uint64(31))


@dataclasses.dataclass
class ByteCorpus:
    """A byte-level corpus with train/valid/test splits and a dense vocab."""

    data: np.ndarray          # uint8/uint16 token ids, full corpus
    vocab: int
    itos: np.ndarray          # id -> byte value
    splits: dict              # name -> (start, end)

    @classmethod
    def from_bytes(cls, raw: bytes, *, valid_frac: float = 0.05,
                   test_frac: float = 0.05) -> "ByteCorpus":
        arr = np.frombuffer(raw, dtype=np.uint8)
        uniq, inv = np.unique(arr, return_inverse=True)
        data = inv.astype(np.uint16)
        n = len(data)
        nv, nt = int(n * valid_frac), int(n * test_frac)
        splits = {"train": (0, n - nv - nt),
                  "valid": (n - nv - nt, n - nt),
                  "test": (n - nt, n)}
        return cls(data=data, vocab=int(len(uniq)), itos=uniq, splits=splits)

    @classmethod
    def from_files(cls, paths: Iterable[str | Path], **kw) -> "ByteCorpus":
        raw = b"\n".join(Path(p).read_bytes() for p in sorted(map(str, paths)))
        return cls.from_bytes(raw, **kw)

    @classmethod
    def from_dir(cls, root: str | Path, suffixes: Sequence[str] = (".py", ".md"),
                 limit_bytes: int = 8_000_000, **kw) -> "ByteCorpus":
        """Corpus from a source tree (the offline stand-in for Linux-Kernel/
        War&Peace style corpora; real deployments point this at the dataset)."""
        files, total = [], 0
        for p in sorted(Path(root).rglob("*")):
            if p.suffix in suffixes and p.is_file():
                sz = p.stat().st_size
                if total + sz > limit_bytes:
                    break
                files.append(p)
                total += sz
        return cls.from_files(files, **kw)

    def batch(self, split: str, step: int, batch_size: int, seq: int,
              *, host_id: int = 0, n_hosts: int = 1) -> dict:
        """Deterministic (tokens, targets) for `step`; hosts draw disjoint
        rows of the global batch (rows [host_id*b_local, ...))."""
        s0, s1 = self.splits[split]
        span = s1 - s0 - seq - 1
        b_local = batch_size // n_hosts
        row0 = host_id * b_local
        ctr = (np.uint64(step) << np.uint64(20)) + np.arange(
            row0, row0 + b_local, dtype=np.uint64)
        starts = (s0 + (_mix64(ctr) % np.uint64(span))).astype(np.int64)
        idx = starts[:, None] + np.arange(seq + 1)[None, :]
        windows = self.data[idx]
        return {"tokens": windows[:, :-1].astype(np.int32),
                "targets": windows[:, 1:].astype(np.int32)}

    def decode(self, ids: np.ndarray) -> str:
        return bytes(self.itos[np.asarray(ids)]).decode("utf-8", errors="replace")
