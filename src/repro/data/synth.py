"""Synthetic datasets: learnable stand-ins for the paper's corpora when the
originals aren't on disk (offline container).  All are deterministic in
(seed, step) — stateless restart, same as data/text.py.

  * markov_bytes: an order-2 character process with a skewed transition
    table — has real structure (achievable BPC well below log2(V)), so
    quantized-vs-fp comparisons are meaningful.
  * seq_mnist_like: class-conditional 28x28 binary images (prototype +
    noise) processed pixel-by-pixel, the paper's sequential-MNIST shape.
  * token_stream: uniform token batches for throughput/dry-run work.
"""
from __future__ import annotations

import numpy as np


def markov_bytes(n: int, vocab: int = 64, seed: int = 0,
                 temperature: float = 0.3) -> np.ndarray:
    """Order-2 Markov chain over `vocab` symbols with sparse/skewed rows."""
    rng = np.random.default_rng(seed)
    logits = rng.gumbel(size=(vocab, vocab, vocab)) / temperature
    # sparsify: keep top-8 transitions per context
    k = min(8, vocab)
    thresh = np.partition(logits, -k, axis=-1)[..., -k][..., None]
    logits = np.where(logits >= thresh, logits, -np.inf)
    p = np.exp(logits - logits.max(axis=-1, keepdims=True))
    p /= p.sum(axis=-1, keepdims=True)
    cdf = np.cumsum(p, axis=-1)

    out = np.empty(n, dtype=np.uint16)
    a = b = 0
    u = rng.random(n)
    for i in range(n):
        c = int(np.searchsorted(cdf[a, b], u[i]))
        out[i] = c = min(c, vocab - 1)
        a, b = b, c
    return out


def seq_mnist_like(step: int, batch: int, *, n_classes: int = 10,
                   side: int = 28, noise: float = 0.15, seed: int = 7) -> dict:
    """(images (B, side*side, 1) float32 in {0,1}, labels (B,)) per step."""
    proto_rng = np.random.default_rng(seed)
    protos = (proto_rng.random((n_classes, side * side)) < 0.25).astype(np.float32)
    rng = np.random.default_rng(seed * 1_000_003 + step)
    labels = rng.integers(0, n_classes, size=batch)
    x = protos[labels]
    flip = rng.random((batch, side * side)) < noise
    x = np.where(flip, 1.0 - x, x).astype(np.float32)
    return {"pixels": x[..., None], "labels": labels.astype(np.int32)}


def token_stream(step: int, batch: int, seq: int, vocab: int,
                 seed: int = 0) -> dict:
    rng = np.random.default_rng(seed * 999_983 + step)
    toks = rng.integers(0, vocab, size=(batch, seq + 1), dtype=np.int64)
    return {"tokens": toks[:, :-1].astype(np.int32),
            "targets": toks[:, 1:].astype(np.int32)}
