"""Training launcher: data -> prefetch -> pjit step -> checkpoint/restart.

Runs at every scale with the same code path:
  * CPU/dev box:  python -m repro.launch.train --arch qwen3-0.6b --reduced \
                      --steps 50
  * pod/fleet:    the same command under the TPU runtime with --mesh-model 16
                  (the launcher builds the largest feasible mesh from
                  jax.devices() via train/elastic.py, so losing hosts between
                  restarts re-shapes automatically — elastic scaling).

The paper's own BN-LSTM trains through the same launcher:

  python -m repro.launch.train --arch rnn-paper --reduced --steps 300

routes RNN_ARCH_IDS through get_rnn_config -> make_rnn_train_step (bn_state
threaded through TrainState), evaluates validation BPC on a held-out split,
and drives the paper's /4-on-plateau LR schedule from the journaled eval
curve — the journal is replayed on restart so a resumed run derives the
exact lr_scale the interrupted run was using.

--pipeline closes the whole loop in one command (DESIGN.md §13): train with
a real mid-run SIGTERM + restart, prove the resumed run is bit-identical to
an uninterrupted one, export the trained masters to packed ternary QTensors
with frozen BN statistics, serve them through ServeEngine with byte parity
against the sequential oracle, and measure the trained masters' speculative-
decoding acceptance rate.  Results land in results/benchmarks/train_rnn.json.

Fault-tolerance contract: SIGTERM => checkpoint + exit 43 (launcher restarts
with --resume auto); checkpoints are atomic; the data pipeline is step-
indexed so restart is sample-exact.  Checkpoint index == number of COMPLETED
steps == the next step to run (both the periodic and the preemption path
save the post-update state under step+1).  A per-step EWMA straggler monitor
logs slow hosts (single-host here; the record() feed is a collective on
fleets).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import re
import signal
import subprocess
import sys
import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (ARCH_IDS, RNN_ARCH_IDS, get_config, get_rnn_config,
                           rnn_paper)
from repro.core import bnlstm as BL
from repro.core.quantize import QuantSpec
from repro.data.loader import Prefetcher
from repro.data.synth import markov_bytes, token_stream
from repro.data.text import ByteCorpus
from repro.launch.sharding import (batch_shardings, param_pspec,
                                   state_shardings)
from repro.runtime import use_mesh
from repro.train import checkpoint as CK
from repro.train.elastic import best_mesh_shape, make_mesh_from_plan
from repro.train.fault_tolerance import (RESTART_EXIT_CODE, PreemptionHandler,
                                         StepTimer, StragglerMonitor)
from repro.train.optimizer import OptConfig, PlateauLR
from repro.train.train_step import (make_rnn_eval, make_rnn_train_step,
                                    make_train_step, train_state_init)
from repro.models import transformer as T

RESULTS = Path(__file__).resolve().parents[3] / "results" / "benchmarks"


def build_argparser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS + RNN_ARCH_IDS,
                    default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config of the same family")
    ap.add_argument("--quant", default=None,
                    choices=("none", "binary", "ternary"),
                    help="override the config's weight quantization")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--opt", default=None, choices=("adamw", "sgd"),
                    help="optimizer (default: adamw)")
    ap.add_argument("--momentum", type=float, default=0.0,
                    help="SGD momentum (paper word-PTB uses plain SGD)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--data", default="synthetic",
                    help="'synthetic' | path to a text file/dir")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="none", choices=("none", "auto"))
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    # RNN (rnn-paper) training
    ap.add_argument("--eval-every", type=int, default=50,
                    help="validation-BPC cadence; drives the plateau LR")
    ap.add_argument("--eval-batches", type=int, default=4)
    ap.add_argument("--plateau-factor", type=float, default=0.25,
                    help="LR multiplier on val rise (paper: /4); 0 disables")
    # the one-command train->restart->export->serve proof
    ap.add_argument("--pipeline", action="store_true",
                    help="train with a real SIGTERM restart, verify the "
                         "resume bit-exactly, export packed weights, serve "
                         "through ServeEngine; writes "
                         "results/benchmarks/train_rnn.json")
    ap.add_argument("--quick", action="store_true",
                    help="CI scale for --pipeline (fewer steps)")
    return ap


# ---------------------------------------------------------------------------
# the paper's BN-LSTM / BN-GRU
# ---------------------------------------------------------------------------


def _rnn_corpus(args) -> ByteCorpus:
    """Byte corpus with train/valid/test splits.  'synthetic' generates the
    order-2 Markov stand-in matched to char-PTB's ~50-symbol vocab (offline
    container; see benchmarks/common.py for the caveats on absolute BPC)."""
    if args.data == "synthetic":
        data = np.asarray(markov_bytes(120_000, vocab=50, seed=args.seed))
        return ByteCorpus.from_bytes(bytes(bytearray(data % 256)))
    p = Path(args.data)
    return ByteCorpus.from_dir(p) if p.is_dir() else ByteCorpus.from_files([p])


def _rnn_cfg(args, corpus: ByteCorpus) -> BL.RNNConfig:
    cfg = get_rnn_config(args.arch)
    if args.reduced:
        cfg = rnn_paper.reduced(cfg)
    if args.quant is not None:
        spec = (QuantSpec(mode=args.quant, norm="batch")
                if args.quant != "none" else QuantSpec(mode="none"))
        cfg = dataclasses.replace(cfg, quant=spec)
    # the corpus' dense byte vocab is the model's vocab (it can be smaller
    # than the config's nominal size when symbols are unused)
    return dataclasses.replace(cfg, vocab=corpus.vocab)


def _read_curve(path: Path) -> list:
    if not path.exists():
        return []
    return [json.loads(l) for l in path.read_text().splitlines() if l.strip()]


def run_rnn(args):
    """Train the paper's BN-LSTM char-LM; returns the final TrainState."""
    corpus = _rnn_corpus(args)
    cfg = _rnn_cfg(args, corpus)
    print(f"rnn-paper: cell={cfg.cell} hidden={cfg.d_hidden} "
          f"vocab={cfg.vocab} quant={cfg.quant.mode} "
          f"corpus={len(corpus.data)} tokens", flush=True)

    mesh = None
    if args.compress_grads:
        # pure data parallelism over whatever devices exist (a 1-device mesh
        # still exercises the shard_map compressed path end-to-end)
        mesh = jax.make_mesh((len(jax.devices()),), ("data",))

    opt_cfg = OptConfig(kind=args.opt or "adamw", lr=args.lr,
                        momentum=args.momentum, clip_norm=1.0,
                        warmup_steps=args.warmup)
    var = BL.rnn_lm_init(jax.random.PRNGKey(args.seed), cfg)
    state = train_state_init(var["params"], opt_cfg,
                             jax.random.PRNGKey(args.seed + 1),
                             bn_state=var["state"],
                             compress=args.compress_grads)
    jstep = jax.jit(make_rnn_train_step(cfg, opt_cfg, mesh=mesh,
                                        compress_grads=args.compress_grads))
    jeval = jax.jit(make_rnn_eval(cfg))

    def val_bpc(st) -> float:
        bpcs = [float(jeval(st, corpus.batch("valid", i, args.batch,
                                             args.seq))["bpc"])
                for i in range(args.eval_batches)]
        return float(np.mean(bpcs))

    plateau = PlateauLR(factor=args.plateau_factor or 0.25)
    start_step = 0
    ckpt = None
    curve_path = None
    if args.ckpt_dir:
        ckpt = CK.AsyncCheckpointer(args.ckpt_dir)
        Path(args.ckpt_dir).mkdir(parents=True, exist_ok=True)
        curve_path = Path(args.ckpt_dir) / "val_curve.jsonl"
        if args.resume == "auto" and CK.latest_step(args.ckpt_dir) is not None:
            start_step = CK.latest_step(args.ckpt_dir)
            state = CK.restore(state, args.ckpt_dir, start_step)
            # rebuild the plateau schedule from the journaled eval curve:
            # entries past the checkpoint (eval ran, save didn't) are
            # truncated so the resumed run re-derives them identically
            curve = [e for e in _read_curve(curve_path)
                     if e["step"] <= start_step]
            curve_path.write_text(
                "".join(json.dumps(e) + "\n" for e in curve))
            scale0 = plateau.replay([e["val_bpc"] for e in curve])
            print(f"resumed from step {start_step} "
                  f"(lr_scale {scale0} from {len(curve)} journaled evals)",
                  flush=True)

    handler = PreemptionHandler()
    monitor = StragglerMonitor(n_hosts=jax.process_count())
    prefetch = Prefetcher(
        lambda s: corpus.batch("train", s, args.batch, args.seq),
        start_step, mesh=mesh)

    scale = plateau.scale
    t_start = time.time()
    with use_mesh(mesh):
        for step, batch in prefetch:
            if step >= args.steps:
                break
            with StepTimer() as tm:
                state, metrics = jstep(state, batch,
                                       jnp.asarray(scale, jnp.float32))
                jax.block_until_ready(metrics["loss"])
            monitor.record(jax.process_index(), tm.dt)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:6d} loss {float(metrics['loss']):.4f} "
                      f"bpc {float(metrics['bpc']):.3f} "
                      f"lr {float(metrics.get('lr', 0)):.2e} "
                      f"{tm.dt*1e3:.0f} ms", flush=True)
            done = step + 1
            if args.plateau_factor and (done % args.eval_every == 0
                                        or done == args.steps):
                v = val_bpc(state)
                scale = plateau.update(v)
                print(f"eval  step {done:6d} val_bpc {v:.4f} "
                      f"lr_scale {scale}", flush=True)
                if curve_path is not None:
                    with curve_path.open("a") as f:
                        f.write(json.dumps({"step": done, "val_bpc": v})
                                + "\n")
            if ckpt and done % args.ckpt_every == 0 and done < args.steps:
                ckpt.save_async(state, done)
            if handler.preempted:
                print("preempted: checkpointing and exiting 43", flush=True)
                if ckpt:
                    ckpt.wait()
                    CK.save(state, args.ckpt_dir, done)
                prefetch.close()
                sys.exit(RESTART_EXIT_CODE)

    prefetch.close()
    if ckpt:
        ckpt.wait()
        CK.save(state, args.ckpt_dir, args.steps)
    dt = time.time() - t_start
    print(f"done: {args.steps - start_step} steps in {dt:.1f}s "
          f"({(args.steps - start_step) / max(dt, 1e-9):.2f} steps/s)")
    return state


# ---------------------------------------------------------------------------
# the one-command pipeline: train -> SIGTERM/restart -> export -> serve
# ---------------------------------------------------------------------------


def _child_cmd(args, ckpt_dir: Path) -> list:
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", args.arch, "--steps", str(args.steps),
           "--batch", str(args.batch), "--seq", str(args.seq),
           "--lr", str(args.lr), "--warmup", str(args.warmup),
           "--momentum", str(args.momentum), "--seed", str(args.seed),
           "--data", args.data, "--ckpt-dir", str(ckpt_dir),
           "--ckpt-every", str(args.ckpt_every), "--resume", "auto",
           "--log-every", str(args.log_every),
           "--eval-every", str(args.eval_every),
           "--eval-batches", str(args.eval_batches),
           "--plateau-factor", str(args.plateau_factor)]
    if args.reduced:
        cmd.append("--reduced")
    if args.quant is not None:
        cmd += ["--quant", args.quant]
    if args.opt is not None:
        cmd += ["--opt", args.opt]
    if args.compress_grads:
        cmd.append("--compress-grads")
    return cmd


def _run_leg(cmd: list, tag: str, kill_at_step: int | None = None) -> int:
    """Run one training leg as a subprocess; with kill_at_step, deliver a
    real SIGTERM once the child logs that step, and expect exit 43."""
    proc = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True,
                            env=os.environ.copy())
    killed = False
    pat = (re.compile(rf"^step\s+{kill_at_step}\b")
           if kill_at_step is not None else None)
    for line in proc.stdout:
        print(f"  [{tag}] {line}", end="", flush=True)
        if pat is not None and not killed and pat.match(line.strip()):
            proc.send_signal(signal.SIGTERM)
            killed = True
    rc = proc.wait()
    want = RESTART_EXIT_CODE if kill_at_step is not None else 0
    if kill_at_step is not None and not killed:
        raise SystemExit(f"pipeline: never saw 'step {kill_at_step}' in "
                         f"{tag} output")
    if rc != want:
        raise SystemExit(f"pipeline: {tag} exited {rc}, expected {want}")
    return rc


def _ckpt_bit_equal(a: Path, b: Path) -> bool:
    """Leaf-for-leaf bitwise comparison of two step_<n> checkpoints."""
    ma = json.loads((a / "manifest.json").read_text())
    mb = json.loads((b / "manifest.json").read_text())
    if sorted(ma["leaves"]) != sorted(mb["leaves"]):
        return False
    for key in ma["leaves"]:
        xa = np.load(a / "shard_00000" / f"{key}.npy")
        xb = np.load(b / "shard_00000" / f"{key}.npy")
        if xa.dtype != xb.dtype or xa.shape != xb.shape:
            return False
        if xa.tobytes() != xb.tobytes():
            return False
    return True


def run_rnn_pipeline(args):
    """train -> checkpoint -> SIGTERM restart -> export -> serve, asserted.

    Leg A trains with a REAL mid-run SIGTERM (delivered by this parent when
    the child logs the kill step), restarts via --resume auto, and finishes.
    Leg B trains the same command uninterrupted in a separate directory.
    The two final checkpoints must be bit-identical — that is the
    sample-exact-resume claim, proven on the actual launcher process
    boundary rather than in-process.  The trained masters then flow through
    export_packed_rnn (frozen BN) into ServeEngine with byte parity against
    the sequential oracle, and the fp-master/ternary-draft speculation pair
    measures the trained accept rate."""
    if args.quick:
        args.steps = min(args.steps, 60)
        args.eval_every = min(args.eval_every, 20)
        args.ckpt_every = min(args.ckpt_every, 10)
    args.log_every = min(args.log_every, 10)
    kill_at = max((args.steps // 2) // args.log_every, 1) * args.log_every

    made_tmp = args.ckpt_dir is None
    base = Path(args.ckpt_dir) if args.ckpt_dir else Path(
        tempfile.mkdtemp(prefix="rnn_pipeline_"))
    dir_a, dir_b = base / "interrupted", base / "straight"
    rows = []

    # --- leg A: train, SIGTERM at kill_at, restart, finish ------------------
    print(f"pipeline: leg A trains {args.steps} steps with SIGTERM at "
          f"step {kill_at}, then resumes", flush=True)
    cmd_a = _child_cmd(args, dir_a)
    t0 = time.time()
    _run_leg(cmd_a, "train-A", kill_at_step=kill_at)
    resumed_from = CK.latest_step(dir_a)
    _run_leg(cmd_a, "train-A-resume")
    # --- leg B: the uninterrupted reference ---------------------------------
    print("pipeline: leg B trains the same run uninterrupted", flush=True)
    _run_leg(_child_cmd(args, dir_b), "train-B")
    train_s = time.time() - t0

    final = f"step_{args.steps:08d}"
    exact = _ckpt_bit_equal(dir_a / final, dir_b / final)
    curve = _read_curve(dir_b / "val_curve.jsonl")
    print(f"pipeline: resume bit-exact vs uninterrupted: {exact} "
          f"(restarted from step {resumed_from})", flush=True)
    if not exact:
        raise SystemExit("pipeline: resumed run diverged from the "
                         "uninterrupted reference")
    rows.append({
        "phase": "train+restart", "steps": args.steps,
        "sigterm_at_step": kill_at, "resumed_from_step": resumed_from,
        "restart_exit_code": RESTART_EXIT_CODE,
        "resume_bit_exact": exact,
        "val_bpc_curve": [{"step": e["step"],
                           "val_bpc": round(e["val_bpc"], 4)}
                          for e in curve],
        "final_val_bpc": round(curve[-1]["val_bpc"], 4) if curve else None,
        "train_wall_s": round(train_s, 1),
    })

    # --- export the trained masters and serve them --------------------------
    from repro.core.qtensor import tree_nbytes
    from repro.serve.engine import Request, ServeEngine
    from repro.serve.recurrent import (RNNRuntime, drive_session,
                                       speculative_draft)

    corpus = _rnn_corpus(args)
    cfg = _rnn_cfg(args, corpus)
    opt_cfg = OptConfig(kind=args.opt or "adamw", lr=args.lr,
                        momentum=args.momentum, clip_norm=1.0,
                        warmup_steps=args.warmup)
    var = BL.rnn_lm_init(jax.random.PRNGKey(args.seed), cfg)
    template = train_state_init(var["params"], opt_cfg,
                                jax.random.PRNGKey(args.seed + 1),
                                bn_state=var["state"],
                                compress=args.compress_grads)
    trained = CK.restore(template, dir_b, args.steps)

    mode = cfg.quant.mode if cfg.quant.mode != "none" else "ternary"
    qvar = BL.serving_variables(trained.params, trained.bn_state, cfg)
    fp_b, packed_b = tree_nbytes(qvar["params"])
    rt_packed = RNNRuntime(cfg, qvar)
    print(f"pipeline: exported packed {mode} weights "
          f"({fp_b/1e6:.2f} MB fp32 -> {packed_b/1e6:.2f} MB, "
          f"{fp_b/max(packed_b,1):.1f}x), BN statistics frozen", flush=True)

    # byte parity: the engine's per-request streams vs the sequential oracle
    rng = np.random.default_rng(7)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab,
                                        size=int(rng.integers(2, 10))),
                    max_tokens=int(rng.integers(2, 9)),
                    temperature=0.8, top_k=5, seed=100 + i, rid=i)
            for i in range(5)]
    eng = ServeEngine(rt_packed, cfg.vocab, slots=2, max_context=64,
                      prefill_chunk=4)
    comps, _ = eng.run([dataclasses.replace(r) for r in reqs],
                       realtime=False)
    by_rid = {c.rid: c for c in comps}
    for r in reqs:
        out, _ = drive_session(
            rt_packed, jnp.asarray(np.asarray(r.prompt, np.int32))[None],
            cfg.vocab, gen=r.max_tokens, temperature=r.temperature,
            top_k=r.top_k, seed=r.seed)
        if by_rid[r.rid].tokens != out[0].tolist():
            raise SystemExit(f"pipeline: engine stream for request {r.rid} "
                             "diverged from the sequential oracle")
    print(f"pipeline: ServeEngine byte parity vs sequential oracle over "
          f"{len(reqs)} requests", flush=True)
    rows.append({"phase": "export+serve", "quant": mode,
                 "fp32_mb": round(fp_b / 1e6, 3),
                 "packed_mb": round(packed_b / 1e6, 3),
                 "engine_byte_parity": True, "parity_requests": len(reqs)})

    # trained-master speculation: fp target, packed draft, greedy drain
    fp_cfg = dataclasses.replace(cfg, quant=QuantSpec(mode="none"))
    rt_fp = RNNRuntime(fp_cfg, {"params": trained.params,
                                "state": trained.bn_state})
    draft = speculative_draft(rt_fp, mode=mode)
    prompt_len, gen, spec_k = 6, 32 if args.quick else 48, 4
    greedy = [Request(prompt=rng.integers(0, cfg.vocab, size=prompt_len),
                      max_tokens=gen, temperature=0.0, top_k=0,
                      seed=500 + i, rid=i) for i in range(4)]
    lens = [prompt_len] * len(greedy)
    ctx = prompt_len + gen
    plain = ServeEngine(rt_fp, cfg.vocab, slots=1, max_context=ctx,
                        prefill_chunk=8)
    spec = ServeEngine(rt_fp, cfg.vocab, slots=1, max_context=ctx,
                       prefill_chunk=8, draft=draft, spec_k=spec_k)
    plain.warm(lens)
    spec.warm(lens)
    _, mp = plain.run([dataclasses.replace(r) for r in greedy],
                      realtime=False)
    _, ms = spec.run([dataclasses.replace(r) for r in greedy],
                     realtime=False)
    print(f"pipeline: trained-master speculation k={spec_k} accept rate "
          f"{ms['accept_rate']:.3f}, {ms['agg_tok_s']:.0f} tok/s spec vs "
          f"{mp['agg_tok_s']:.0f} plain", flush=True)
    if not args.quick:
        assert ms["accept_rate"] > 0.6, ms["accept_rate"]
    rows.append({"phase": "speculation", "spec_k": spec_k,
                 "accept_rate": round(ms["accept_rate"], 3),
                 "drafted_tokens": ms["drafted_tokens"],
                 "plain_tok_s": round(mp["agg_tok_s"], 1),
                 "spec_tok_s": round(ms["agg_tok_s"], 1),
                 "speedup_vs_plain": round(ms["agg_tok_s"]
                                           / max(mp["agg_tok_s"], 1e-9), 2),
                 "asserted": not args.quick})

    RESULTS.mkdir(parents=True, exist_ok=True)
    payload = {"meta": {"arch": args.arch, "reduced": args.reduced,
                        "hidden": cfg.d_hidden, "vocab": cfg.vocab,
                        "cell": cfg.cell, "quant": cfg.quant.mode,
                        "corpus": args.data, "steps": args.steps,
                        "batch": args.batch, "seq": args.seq,
                        "opt": opt_cfg.kind, "lr": args.lr,
                        "quick": args.quick,
                        "backend": jax.default_backend(),
                        "note": "reduced-scale synthetic corpus: relative "
                                "claims only; absolute BPC is not "
                                "comparable to the paper's tables"},
               "rows": rows}
    out = RESULTS / "train_rnn.json"
    out.write_text(json.dumps(payload, indent=1))
    print(f"pipeline: wrote {out}", flush=True)
    if made_tmp:
        import shutil
        shutil.rmtree(base, ignore_errors=True)
    return rows


# ---------------------------------------------------------------------------
# transformer pool
# ---------------------------------------------------------------------------


def run_transformer(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.quant is not None:
        cfg = cfg.with_quant(QuantSpec(mode=args.quant, norm="channel")
                             if args.quant != "none" else QuantSpec(mode="none"))

    # --- data --------------------------------------------------------------
    if args.data == "synthetic":
        vocab = cfg.vocab
        make_batch = lambda s: token_stream(s, args.batch, args.seq, vocab,
                                            seed=args.seed)
    else:
        p = Path(args.data)
        corpus = (ByteCorpus.from_dir(p) if p.is_dir()
                  else ByteCorpus.from_files([p]))
        if corpus.vocab > cfg.vocab:
            raise SystemExit(f"corpus vocab {corpus.vocab} > model {cfg.vocab}")
        make_batch = lambda s: corpus.batch("train", s, args.batch, args.seq)

    # --- mesh (elastic: derive from live devices) ---------------------------
    n_dev = len(jax.devices())
    mesh = None
    if n_dev > 1:
        plan = best_mesh_shape(n_dev, want_model=args.mesh_model,
                               global_batch=args.batch)
        mesh = make_mesh_from_plan(plan)
        print(f"mesh: {dict(zip(plan.axes, plan.shape))}, "
              f"per-replica batch {plan.per_replica_batch}, "
              f"dropped {plan.dropped_devices} devices")

    opt_cfg = OptConfig(kind=args.opt or "adamw", lr=args.lr,
                        momentum=args.momentum, warmup_steps=args.warmup,
                        decay_steps=args.steps, clip_norm=1.0)

    params = T.model_init(jax.random.PRNGKey(args.seed), cfg)
    state = train_state_init(params, opt_cfg, jax.random.PRNGKey(args.seed + 1),
                             compress=args.compress_grads)
    step_fn = make_train_step(cfg, opt_cfg, mesh=mesh,
                              compress_grads=args.compress_grads)

    start_step = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = CK.AsyncCheckpointer(args.ckpt_dir)
        if args.resume == "auto" and CK.latest_step(args.ckpt_dir) is not None:
            start_step = CK.latest_step(args.ckpt_dir)
            state = CK.restore(state, args.ckpt_dir, start_step)
            print(f"resumed from step {start_step}")

    if mesh is not None:
        st_sh = state_shardings(state, mesh)
        b_sh = batch_shardings(make_batch(0), mesh)
        jstep = jax.jit(step_fn, in_shardings=(st_sh, b_sh),
                        out_shardings=(st_sh, None))
    else:
        jstep = jax.jit(step_fn)

    handler = PreemptionHandler()
    monitor = StragglerMonitor(n_hosts=jax.process_count())
    prefetch = Prefetcher(make_batch, start_step, mesh=mesh)

    t_start = time.time()
    with use_mesh(mesh, param_rules=param_pspec):
        for step, batch in prefetch:
            if step >= args.steps:
                break
            with StepTimer() as tm:
                state, metrics = jstep(state, batch)
                jax.block_until_ready(metrics["loss"])
            monitor.record(jax.process_index(), tm.dt)

            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                print(f"step {step:6d} loss {loss:.4f} "
                      f"lr {float(metrics.get('lr', 0)):.2e} "
                      f"gnorm {float(metrics.get('grad_norm', 0)):.2f} "
                      f"{tm.dt*1e3:.0f} ms", flush=True)
            # checkpoint index == COMPLETED steps (step+1): a restart resumes
            # at the next step and replays nothing — the same convention as
            # the preemption path below, so periodic and preemption restores
            # are both sample-exact
            done = step + 1
            if ckpt and done % args.ckpt_every == 0 and done < args.steps:
                ckpt.save_async(state, done)
            if handler.preempted:
                print("preempted: checkpointing and exiting 43", flush=True)
                if ckpt:
                    ckpt.wait()
                    CK.save(state, args.ckpt_dir, done)
                prefetch.close()
                sys.exit(RESTART_EXIT_CODE)

    prefetch.close()
    if ckpt:
        ckpt.wait()
        CK.save(state, args.ckpt_dir, args.steps)
    dt = time.time() - t_start
    print(f"done: {args.steps - start_step} steps in {dt:.1f}s "
          f"({(args.steps - start_step) / max(dt, 1e-9):.2f} steps/s)")
    return state


def main(argv=None):
    args = build_argparser().parse_args(argv)
    if args.arch in RNN_ARCH_IDS:
        return run_rnn_pipeline(args) if args.pipeline else run_rnn(args)
    if args.pipeline:
        raise SystemExit("--pipeline is the rnn-paper train->serve proof; "
                         "run it with --arch rnn-paper")
    return run_transformer(args)


if __name__ == "__main__":
    main()
