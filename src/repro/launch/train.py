"""Training launcher: data -> prefetch -> pjit step -> checkpoint/restart.

Runs at every scale with the same code path:
  * CPU/dev box:  python -m repro.launch.train --arch qwen3-0.6b --reduced \
                      --steps 50
  * pod/fleet:    the same command under the TPU runtime with --mesh-model 16
                  (the launcher builds the largest feasible mesh from
                  jax.devices() via train/elastic.py, so losing hosts between
                  restarts re-shapes automatically — elastic scaling).

Fault-tolerance contract: SIGTERM => checkpoint + exit 43 (launcher restarts
with --resume auto); checkpoints are atomic; the data pipeline is step-
indexed so restart is sample-exact.  A per-step EWMA straggler monitor logs
slow hosts (single-host here; the record() feed is a collective on fleets).
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core.quantize import QuantSpec
from repro.data.loader import Prefetcher
from repro.data.synth import token_stream
from repro.data.text import ByteCorpus
from repro.launch.sharding import (batch_shardings, param_pspec,
                                   state_shardings)
from repro.runtime import use_mesh
from repro.train import checkpoint as CK
from repro.train.elastic import best_mesh_shape, make_mesh_from_plan
from repro.train.fault_tolerance import (RESTART_EXIT_CODE, PreemptionHandler,
                                         StepTimer, StragglerMonitor)
from repro.train.optimizer import OptConfig
from repro.train.train_step import make_train_step, train_state_init
from repro.models import transformer as T


def build_argparser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config of the same family")
    ap.add_argument("--quant", default=None,
                    choices=("none", "binary", "ternary"),
                    help="override the config's weight quantization")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--data", default="synthetic",
                    help="'synthetic' | path to a text file/dir")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="none", choices=("none", "auto"))
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    return ap


def main(argv=None):
    args = build_argparser().parse_args(argv)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.quant is not None:
        cfg = cfg.with_quant(QuantSpec(mode=args.quant, norm="channel")
                             if args.quant != "none" else QuantSpec(mode="none"))

    # --- data --------------------------------------------------------------
    if args.data == "synthetic":
        vocab = cfg.vocab
        make_batch = lambda s: token_stream(s, args.batch, args.seq, vocab,
                                            seed=args.seed)
    else:
        p = Path(args.data)
        corpus = (ByteCorpus.from_dir(p) if p.is_dir()
                  else ByteCorpus.from_files([p]))
        if corpus.vocab > cfg.vocab:
            raise SystemExit(f"corpus vocab {corpus.vocab} > model {cfg.vocab}")
        make_batch = lambda s: corpus.batch("train", s, args.batch, args.seq)

    # --- mesh (elastic: derive from live devices) ---------------------------
    n_dev = len(jax.devices())
    mesh = None
    if n_dev > 1:
        plan = best_mesh_shape(n_dev, want_model=args.mesh_model,
                               global_batch=args.batch)
        mesh = make_mesh_from_plan(plan)
        print(f"mesh: {dict(zip(plan.axes, plan.shape))}, "
              f"per-replica batch {plan.per_replica_batch}, "
              f"dropped {plan.dropped_devices} devices")

    opt_cfg = OptConfig(kind="adamw", lr=args.lr, warmup_steps=args.warmup,
                        decay_steps=args.steps, clip_norm=1.0)

    params = T.model_init(jax.random.PRNGKey(args.seed), cfg)
    state = train_state_init(params, opt_cfg, jax.random.PRNGKey(args.seed + 1),
                             compress=args.compress_grads)
    step_fn = make_train_step(cfg, opt_cfg, mesh=mesh,
                              compress_grads=args.compress_grads)

    start_step = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = CK.AsyncCheckpointer(args.ckpt_dir)
        if args.resume == "auto" and CK.latest_step(args.ckpt_dir) is not None:
            start_step = CK.latest_step(args.ckpt_dir)
            state = CK.restore(state, args.ckpt_dir, start_step)
            print(f"resumed from step {start_step}")

    if mesh is not None:
        st_sh = state_shardings(state, mesh)
        b_sh = batch_shardings(make_batch(0), mesh)
        jstep = jax.jit(step_fn, in_shardings=(st_sh, b_sh),
                        out_shardings=(st_sh, None))
    else:
        jstep = jax.jit(step_fn)

    handler = PreemptionHandler()
    monitor = StragglerMonitor(n_hosts=jax.process_count())
    prefetch = Prefetcher(make_batch, start_step, mesh=mesh)

    t_start = time.time()
    with use_mesh(mesh, param_rules=param_pspec):
        for step, batch in prefetch:
            if step >= args.steps:
                break
            with StepTimer() as tm:
                state, metrics = jstep(state, batch)
                jax.block_until_ready(metrics["loss"])
            monitor.record(jax.process_index(), tm.dt)

            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                print(f"step {step:6d} loss {loss:.4f} "
                      f"lr {float(metrics.get('lr', 0)):.2e} "
                      f"gnorm {float(metrics.get('grad_norm', 0)):.2f} "
                      f"{tm.dt*1e3:.0f} ms", flush=True)
            if ckpt and step > 0 and step % args.ckpt_every == 0:
                ckpt.save_async(state, step)
            if handler.preempted:
                print("preempted: checkpointing and exiting 43", flush=True)
                if ckpt:
                    ckpt.wait()
                    CK.save(state, args.ckpt_dir, step + 1)
                prefetch.close()
                sys.exit(RESTART_EXIT_CODE)

    prefetch.close()
    if ckpt:
        ckpt.wait()
        CK.save(state, args.ckpt_dir, args.steps)
    dt = time.time() - t_start
    print(f"done: {args.steps - start_step} steps in {dt:.1f}s "
          f"({(args.steps - start_step) / max(dt, 1e-9):.2f} steps/s)")
    return state


if __name__ == "__main__":
    main()
