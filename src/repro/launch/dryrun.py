"""Multi-pod dry-run: lower + compile every (architecture x input shape) cell
on the production meshes and extract the roofline terms (EXPERIMENTS.md
§Dry-run / §Roofline).

The os.environ lines below MUST run before any jax import — jax locks the
device count at first init.  Do not move them.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
  python -m repro.launch.dryrun --all --mesh single --skip-done   # resume
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import time
import traceback
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import (SHAPES, applicable, decode_context,
                                  decode_inputs, prefill_inputs, token_batch)
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import build as build_roofline
from repro.launch.roofline import collective_wire_bytes
from repro.launch.sharding import (batch_shardings, cache_shardings,
                                   compute_param_pspec, param_pspec,
                                   param_shardings, serve_param_pspec,
                                   serve_param_shardings, state_shardings)
from repro.models import transformer as T
from repro.runtime import use_mesh
from repro.train.optimizer import OptConfig
from repro.train.train_step import make_train_step, train_state_init

jax.config.update("jax_default_prng_impl", "rbg")  # cheap keys for eval_shape


def _params_struct(cfg):
    key = jax.ShapeDtypeStruct((4,), jnp.uint32)
    return jax.eval_shape(lambda k: T.model_init(k, cfg), key)


def _state_struct(cfg, opt_cfg):
    key = jax.ShapeDtypeStruct((4,), jnp.uint32)

    def mk(k):
        params = T.model_init(k, cfg)
        return train_state_init(params, opt_cfg, k)

    return jax.eval_shape(mk, key)


VARIANTS = ("baseline", "packed", "servetp", "dots", "parallel",
            "packed+servetp", "packed+dots", "parallel+dots",
            "parallel+packed+dots")


def apply_variant(cfg, variant: str):
    """Beyond-paper optimization toggles (EXPERIMENTS.md §Perf):
      packed  — FSDP/TP weight gathers move 2-bit/1-bit codes
      servetp — serve cells store weights TP-only + bf16 (no per-token gather)
      dots    — remat policy saves matmul outputs (~8ND -> 6ND train flops)
    """
    parts = set(variant.split("+"))
    if "packed" in parts and cfg.quant.mode in ("binary", "ternary"):
        cfg = cfg.with_quant(dataclasses.replace(cfg.quant, packed_comms=True))
    if "dots" in parts:
        cfg = dataclasses.replace(cfg, remat_policy="dots")
    if "parallel" in parts:
        cfg = dataclasses.replace(cfg, parallel_block=True)
    return cfg, ("servetp" in parts)


def lower_cell(cfg, shape_name: str, multi_pod: bool, serve_tp: bool = False):
    """Returns (lowered, n_chips, meta) for one grid cell."""
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    opt_cfg = OptConfig(kind="adamw", lr=1e-4)

    serve_cell = shape.kind in ("prefill", "decode")
    rules = serve_param_pspec if (serve_tp and serve_cell) else param_pspec
    p_shard_fn = serve_param_shardings if (serve_tp and serve_cell) \
        else param_shardings

    def params_struct():
        params = _params_struct(cfg)
        if serve_tp and serve_cell:
            # deployment layout: bf16 weights, no fp32 masters on the pod
            params = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
                if jnp.issubdtype(s.dtype, jnp.floating) else s, params)
        return params

    with use_mesh(mesh, param_rules=rules, compute_rules=compute_param_pspec):
        if shape.kind == "train":
            state = _state_struct(cfg, opt_cfg)
            batch = token_batch(cfg, shape.global_batch, shape.seq_len)
            in_sh = (state_shardings(state, mesh), batch_shardings(batch, mesh))
            step = make_train_step(cfg, opt_cfg)
            lowered = jax.jit(step, in_shardings=in_sh,
                              out_shardings=(in_sh[0], None)).lower(state, batch)
        elif shape.kind == "prefill":
            params = params_struct()
            inputs = prefill_inputs(cfg, shape.global_batch, shape.seq_len)
            ctx, src = decode_context(cfg, shape.seq_len)
            caches = jax.eval_shape(
                lambda: T.init_caches(cfg, shape.global_batch, ctx, src_len=src))
            p_sh = p_shard_fn(params, mesh)
            c_sh = cache_shardings(caches, mesh)
            i_sh = batch_shardings(inputs, mesh)

            def step(params, caches, inputs):
                return T.prefill(params, inputs["tokens"], caches, cfg,
                                 img=inputs.get("img"),
                                 enc_frames=inputs.get("enc_frames"))

            lowered = jax.jit(step, in_shardings=(p_sh, c_sh, i_sh),
                              out_shardings=(None, c_sh)).lower(
                                  params, caches, inputs)
        else:  # decode
            params = params_struct()
            inputs = decode_inputs(cfg, shape.global_batch)
            ctx, src = decode_context(cfg, shape.seq_len)
            caches = jax.eval_shape(
                lambda: T.init_caches(cfg, shape.global_batch, ctx, src_len=src))
            # decode against a FULL cache: pos = context length
            caches = jax.tree.map(lambda x: x, caches)
            p_sh = p_shard_fn(params, mesh)
            c_sh = cache_shardings(caches, mesh)
            i_sh = batch_shardings(inputs, mesh)

            def step(params, caches, inputs):
                return T.decode_step(params, inputs["token"], caches, cfg)

            lowered = jax.jit(step, in_shardings=(p_sh, c_sh, i_sh),
                              out_shardings=(None, c_sh)).lower(
                                  params, caches, inputs)
    return lowered, n_chips, {"mesh": tuple(mesh.shape.values())}


def _measure(cfg, shape_name: str, multi_pod: bool,
             serve_tp: bool = False) -> dict:
    t0 = time.time()
    lowered, n_chips, meta = lower_cell(cfg, shape_name, multi_pod, serve_tp)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    return {
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "collectives": collective_wire_bytes(compiled.as_text()),
        "memory": {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
            "output_bytes": getattr(ma, "output_size_in_bytes", 0),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
            "alias_bytes": getattr(ma, "alias_size_in_bytes", 0),
        },
    }


def _combine(main: dict, b1: dict, b0: dict, mult: float) -> dict:
    """main + mult * (b1 - b0) on flops/bytes/collectives.

    XLA's cost_analysis counts a while-loop (lax.scan) body ONCE, not x trip
    count; the corrected totals add (R-1) copies of the measured per-repeat
    body delta.  memory_analysis needs no correction (scan reuses buffers)."""
    out = dict(main)
    for k in ("flops", "bytes_accessed"):
        out[k] = main[k] + mult * max(b1[k] - b0[k], 0.0)
    colls = dict(main["collectives"])
    keys = set(b1["collectives"]) | set(b0["collectives"])
    for k in keys:
        d = b1["collectives"].get(k, 0.0) - b0["collectives"].get(k, 0.0)
        if d > 0:
            colls[k] = colls.get(k, 0.0) + mult * d
    out["collectives"] = colls
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             variant: str = "baseline") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    cell = {"arch": arch, "shape": shape_name, "variant": variant,
            "mesh": "multi" if multi_pod else "single"}
    if not ok:
        cell.update(status="skipped", reason=why)
        return cell
    cfg, serve_tp = apply_variant(cfg, variant)
    try:
        main = _measure(cfg, shape_name, multi_pod, serve_tp)
        cell["raw"] = {k: main[k] for k in ("flops", "bytes_accessed",
                                            "collectives")}

        # scan-trip-count correction via two cheap unrolled aux compiles
        from repro.models.transformer import expand_pattern
        pat, rep, tail = expand_pattern(cfg)
        corrected = main
        if rep > 1:
            per = cfg.attn_every if (cfg.family == "hybrid" and cfg.attn_every)\
                else len(cfg.block_pattern)
            cfg0 = dataclasses.replace(cfg, n_layers=0, n_enc_layers=0,
                                       unroll=True)
            cfg1 = dataclasses.replace(cfg, n_layers=per, n_enc_layers=0,
                                       unroll=True)
            b0 = _measure(cfg0, shape_name, multi_pod, serve_tp)
            b1 = _measure(cfg1, shape_name, multi_pod, serve_tp)
            corrected = _combine(main, b1, b0, rep - 1)
            if (cfg.family == "audio" and cfg.n_enc_layers > 1
                    and shape.kind != "decode"):
                e1 = _measure(dataclasses.replace(cfg, n_layers=0,
                                                  n_enc_layers=1, unroll=True),
                              shape_name, multi_pod, serve_tp)
                corrected = _combine(corrected, e1, b0, cfg.n_enc_layers - 1)

        cell.update(status="ok", **corrected)
        wb = 16
        if "packed" in variant:
            wb = {"ternary": 2, "binary": 1}.get(cfg.quant.mode, 16)
        rf = build_roofline(cell, cfg, shape, main["n_chips"], weight_bits=wb)
        cell["roofline"] = rf.to_json()
    except Exception as e:  # record failures — they are bugs to fix
        cell.update(status="error", error=f"{type(e).__name__}: {e}",
                    traceback=traceback.format_exc()[-2000:])
    return cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--variant", default="baseline", choices=VARIANTS)
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    archs = ARCH_IDS if args.all or not args.arch else (args.arch,)
    shapes = tuple(SHAPES) if args.all or not args.shape else (args.shape,)
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)

    n_ok = n_err = n_skip = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                suffix = "" if args.variant == "baseline" else f"__{args.variant}"
                path = out / f"{arch}__{shape}__{mesh_kind}{suffix}.json"
                if args.skip_done and path.exists():
                    prev = json.loads(path.read_text())
                    if prev.get("status") in ("ok", "skipped"):
                        continue
                cell = run_cell(arch, shape, mesh_kind == "multi",
                                args.variant)
                path.write_text(json.dumps(cell, indent=1))
                st = cell["status"]
                n_ok += st == "ok"
                n_err += st == "error"
                n_skip += st == "skipped"
                msg = ""
                if st == "ok":
                    r = cell["roofline"]
                    msg = (f"dom={r['dominant']} tc={r['t_compute_s']:.3e} "
                           f"tm={r['t_memory_s']:.3e} tx={r['t_collective_s']:.3e} "
                           f"compile={cell['compile_s']}s")
                elif st == "error":
                    msg = cell["error"][:140]
                else:
                    msg = cell["reason"][:80]
                print(f"[{st:7s}] {arch:22s} {shape:12s} {mesh_kind:6s} {msg}",
                      flush=True)
    print(f"done: {n_ok} ok, {n_err} errors, {n_skip} skipped")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
