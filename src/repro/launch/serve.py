"""Serving launcher: prefill a prompt batch, decode with sampling.

  python -m repro.launch.serve --arch qwen3-0.6b --reduced --gen 32 --batch 4
  python -m repro.launch.serve --arch rnn-paper --quant ternary
  python -m repro.launch.serve --arch rnn-paper --traffic --rate 8 \
      --requests 32 --slots 8

Every arch — the transformer pool AND the paper's own BN-LSTM — runs the
same prefill → sample → decode loop through the unified recurrent runtime
(serve/recurrent.py).  With --quant binary|ternary the trained-master tree
is exported ONCE into packed `QTensor`s (core/qtensor.py) and prefill/decode
stream the packed codes through the Pallas kernels — the reported packed MB
is the memory the decode loop actually reads, not an analytic estimate.
For --arch rnn-paper the per-step work is the whole-tick fused kernel
(kernels/decode_step.py): ONE launch per token for all layers + head on
accelerators, the compiled dense fallback on CPU (DESIGN.md §11).  On a pod
the same entry point runs under the production mesh with the decode-time
cache shardings from launch/sharding.py.

--traffic switches from the lockstep batch to the continuous-batching
engine (serve/engine.py): a synthetic Poisson workload with mixed prompt
and generation lengths is replayed against a fixed slot pool, requests are
admitted as slots free up, and the report is aggregate tok/s, slot
occupancy and p50/p95 per-request latency — the serving numbers a fleet
actually provisions against.

--spec-k N adds speculative decoding to --traffic: the fp master tree is
the TARGET and its own packed binary/ternary export (the --quant mode) the
DRAFT — each round the draft proposes N tokens per slot, the target
verifies them in one multi-token step, and rejection sampling keeps the
output distribution exactly the target's (byte-identical at temperature
0).  The report adds the measured acceptance rate and the drafted-token
throughput next to the emitted tok/s.

--listen swaps the synthetic workload for the HTTP/SSE front door
(serve/frontdoor.py): the same warmed engine behind POST /v1/generate and
GET /v1/stats, with client disconnects cancelling mid-flight and (where the
runtime supports it) a prefix-state cache sized by --prefix-cache-mb
serving repeated system prompts from one spliced row copy.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import (ARCH_IDS, RNN_ARCH_IDS, get_config, get_rnn_config,
                           rnn_paper)
from repro.core import bnlstm as BL
from repro.core.qtensor import export_packed, tree_nbytes
from repro.core.quantize import QuantSpec
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine
from repro.serve.recurrent import (RNNRuntime, TransformerRuntime,
                                   drive_session, speculative_draft)


def packed_model_bytes(qparams) -> tuple[int, int]:
    """(fp32-equivalent bytes, actual bytes) of an exported serving tree —
    measured from the real `QTensor.nbytes`, not the analytic formula."""
    return tree_nbytes(qparams)


def _report_bytes(rt, quant: str) -> None:
    fp, packed = rt.param_nbytes()
    print(f"model bytes: fp32 {fp/1e6:.1f} MB -> packed({quant}) "
          f"{packed/1e6:.1f} MB ({fp/packed:.1f}x smaller)")


def _build_rnn(args, key):
    """The paper's BN-LSTM/GRU behind the same serving loop."""
    cfg = get_rnn_config(args.arch)
    if args.reduced:
        cfg = rnn_paper.reduced(cfg)
    spec = (QuantSpec(mode=args.quant, norm="batch")
            if args.quant != "none" else QuantSpec(mode="none"))
    cfg = dataclasses.replace(cfg, quant=spec)
    var = BL.rnn_lm_init(key, cfg)
    params = var["params"]
    if args.quant != "none":
        params = BL.export_packed_rnn(params, cfg)
    rt = RNNRuntime(cfg, {"params": params, "state": var["state"]})
    if args.quant != "none":
        _report_bytes(rt, args.quant)
    return cfg, rt


def _build_transformer(args, key):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = cfg.with_quant(QuantSpec(mode=args.quant, norm="channel")
                         if args.quant != "none" else QuantSpec(mode="none"))
    params = T.model_init(key, cfg)
    if args.quant != "none":
        # the train->serve handoff: masters -> packed QTensors, once.  The
        # decode loop below runs against THIS tree, so the printed packed MB
        # is what the matmuls stream.
        params = export_packed(params, cfg.quant)
    B, S = args.batch, args.prompt_len
    extras = {}
    if cfg.family == "vlm":
        extras["img"] = jax.random.normal(key, (B, cfg.n_img_tokens, cfg.d_model))
    if cfg.family == "audio":
        extras["enc_frames"] = jax.random.normal(key, (B, S, cfg.d_model))
    rt = TransformerRuntime(cfg, params, extras=extras)
    if args.quant != "none":
        _report_bytes(rt, args.quant)
    return cfg, rt


def synth_traffic(vocab: int, *, requests: int, rate: float, prompt_len: int,
                  gen: int, temperature: float, top_k: int,
                  seed: int = 0) -> list:
    """A synthetic mixed-length workload: Poisson arrivals at `rate` req/s,
    prompt lengths U[1, prompt_len], generation lengths U[1, gen] — the
    mixed-depth traffic continuous batching exists for.  Deterministic in
    `seed` so a workload can be replayed across engines / PRs."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(rate, 1e-9), size=requests)
    arrivals = np.cumsum(gaps)
    reqs = []
    for i in range(requests):
        S = int(rng.integers(1, prompt_len + 1))
        n = int(rng.integers(1, gen + 1))
        reqs.append(Request(
            prompt=rng.integers(0, vocab, size=S),
            max_tokens=n, temperature=temperature, top_k=top_k,
            seed=seed + 1000 + i, arrival_s=float(arrivals[i]), rid=i))
    return reqs


def _serve_mesh(args):
    """Resolve --mesh into a Mesh (None when unset) and announce it."""
    if not getattr(args, "mesh", ""):
        return None
    from repro.launch.mesh import make_serve_mesh
    mesh = make_serve_mesh(args.mesh)
    shape = ",".join(f"{a}={n}" for a, n in mesh.shape.items())
    print(f"mesh: {shape} over {mesh.size} device(s) — slot pool sharded "
          f"along 'data', weights tensor-parallel along 'model'")
    return mesh


def run_traffic(cfg, rt, args, draft=None) -> dict:
    """Replay a Poisson workload through the continuous-batching engine."""
    ctx = args.prompt_len + args.gen
    eng = ServeEngine(rt, cfg.vocab, slots=args.slots, max_context=ctx,
                      prefill_chunk=args.prefill_chunk,
                      draft=draft, spec_k=args.spec_k if draft else 0,
                      mesh=_serve_mesh(args))
    reqs = synth_traffic(cfg.vocab, requests=args.requests, rate=args.rate,
                         prompt_len=args.prompt_len, gen=args.gen,
                         temperature=args.temperature, top_k=args.top_k,
                         seed=args.seed)
    # warm the tick and every declared prefill chunk bucket before the
    # clock starts, so latency percentiles measure serving, not XLA
    # compilation (one prefill trace per bucket; the tick never retraces)
    eng.warm([np.asarray(r.prompt).size for r in reqs])
    comps, m = eng.run(reqs, realtime=True)
    print(f"traffic: {m['requests']} requests over {m['wall_s']:.2f}s "
          f"({args.rate:.1f} req/s offered, {args.slots} slots, "
          f"prefill chunk {args.prefill_chunk})")
    print(f"aggregate decode: {m['agg_tok_s']:.1f} tok/s  "
          f"occupancy: {100 * m['occupancy']:.0f}%  "
          f"ticks: {m['ticks']} (traces: {m['tick_traces']}, "
          f"prefill traces: {m['prefill_traces']})")
    print(f"latency: p50 {m['p50_latency_s']*1e3:.0f} ms  "
          f"p95 {m['p95_latency_s']*1e3:.0f} ms  |  "
          f"ttft: p50 {m['ttft_p50_s']*1e3:.0f} ms  "
          f"p95 {m['ttft_p95_s']*1e3:.0f} ms  "
          f"(max decode stall: {m['max_decode_stall_ticks']} chunk)")
    if draft is not None:
        print(f"speculative: k={m['spec_k']}  "
              f"accept rate {100 * m['accept_rate']:.0f}%  "
              f"({m['accepted_drafts']}/{m['drafted_tokens']} drafts over "
              f"{m['spec_rounds']} rounds)  "
              f"draft {m['draft_tok_s']:.1f} tok/s proposed")
    done = sorted(comps, key=lambda c: c.rid)[:4]
    for c in done:
        print(f"  req {c.rid}: prompt {c.prompt_len} -> {len(c.tokens)} toks "
              f"({c.finished}), ttft {c.ttft_s*1e3:.0f} ms, "
              f"latency {c.latency_s*1e3:.0f} ms")
    return m


def run_listen(cfg, rt, args, draft=None) -> None:
    """Serve over HTTP/SSE: build the engine the way --traffic does (same
    warm, same invariants), hand it to the asyncio front door, block."""
    import asyncio

    from repro.serve.frontdoor import FrontDoor
    from repro.serve.prefixcache import PrefixCache

    ctx = args.prompt_len + args.gen
    cache = None
    if args.prefix_cache_mb > 0:
        supported = (getattr(rt, "chunk_granularity", "whole") == "token"
                     and (rt.family == "rnn"
                          or getattr(rt, "pad_buckets", False)))
        if supported:
            cache = PrefixCache(args.prefix_cache_mb << 20)
        else:
            print("prefix cache: unsupported for this runtime "
                  "(needs token-granularity chunking; non-ring caches) "
                  "— serving without it")
    eng = ServeEngine(rt, cfg.vocab, slots=args.slots, max_context=ctx,
                      prefill_chunk=args.prefill_chunk,
                      draft=draft, spec_k=args.spec_k if draft else 0,
                      prefix_cache=cache, mesh=_serve_mesh(args))
    eng.warm([args.prompt_len])

    async def _serve():
        fd = FrontDoor(eng, host=args.host, port=args.port)
        await fd.start()
        print(f"front door listening on http://{fd.host}:{fd.port}  "
              f"({args.slots} slots, ctx {ctx}, chunk {args.prefill_chunk}"
              + (f", prefix cache {args.prefix_cache_mb} MB" if cache
                 else "") + ")")
        print(f"  curl -N -X POST http://{fd.host}:{fd.port}/v1/generate "
              "-d '{\"prompt\": [1,2,3], \"max_tokens\": 16}'")
        try:
            await fd.serve_forever()
        finally:
            await fd.close()

    try:
        asyncio.run(_serve())
    except KeyboardInterrupt:
        pass


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS + RNN_ARCH_IDS,
                    default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--quant", default="ternary",
                    choices=("none", "binary", "ternary"))
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--top-k", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--traffic", action="store_true",
                    help="replay a mixed-length Poisson workload through "
                         "the continuous-batching ServeEngine")
    ap.add_argument("--rate", type=float, default=4.0,
                    help="offered arrival rate, requests/s (--traffic)")
    ap.add_argument("--requests", type=int, default=16,
                    help="workload size (--traffic)")
    ap.add_argument("--slots", type=int, default=4,
                    help="engine slot-pool size (--traffic)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="in-slot prefill chunk size: at most one chunk "
                         "runs between decode ticks, so long prompts never "
                         "stall live decodes (--traffic)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: the packed --quant export "
                         "of the model drafts K tokens per round for the "
                         "fp target to verify (--traffic only; 0 = off)")
    ap.add_argument("--listen", action="store_true",
                    help="serve the engine over HTTP/SSE "
                         "(serve/frontdoor.py) instead of replaying a "
                         "synthetic workload")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8700)
    ap.add_argument("--prefix-cache-mb", type=int, default=64,
                    help="prefix-state cache byte budget for --listen "
                         "(0 = off); repeated system prompts resume from "
                         "a spliced state row instead of re-prefilling")
    ap.add_argument("--mesh", default="",
                    help="serve on a device mesh, e.g. 'data=4,model=2': "
                         "slot pool sharded D-way along 'data' (slots must "
                         "divide D), weights tensor-parallel along 'model' "
                         "(DESIGN.md §12); on CPU run under XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    args = ap.parse_args(argv)

    if args.spec_k and not (args.traffic or args.listen):
        raise SystemExit("--spec-k is a continuous-batching engine mode; "
                         "run it with --traffic or --listen")
    if args.mesh and not (args.traffic or args.listen):
        raise SystemExit("--mesh shards the continuous-batching engine; "
                         "run it with --traffic or --listen")
    key = jax.random.PRNGKey(args.seed)
    build = _build_rnn if args.arch in RNN_ARCH_IDS else _build_transformer
    draft = None
    if args.spec_k:
        # self-speculation: the fp masters ARE the target; --quant names
        # the DRAFT's packing (the default ternary when unset)
        draft_mode = args.quant if args.quant != "none" else "ternary"
        args.quant = "none"
        cfg, rt = build(args, key)
        draft = speculative_draft(rt, mode=draft_mode)
        _report_bytes(draft, draft_mode)
    else:
        cfg, rt = build(args, key)

    if args.listen:
        return run_listen(cfg, rt, args, draft=draft)
    if args.traffic:
        return run_traffic(cfg, rt, args, draft=draft)

    B, S = args.batch, args.prompt_len
    prompt = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0, cfg.vocab)
    out, m = drive_session(rt, prompt, cfg.vocab, gen=args.gen,
                           temperature=args.temperature, top_k=args.top_k,
                           seed=args.seed + 1)
    print(f"session state: {m['state_nbytes']/1e6:.2f} MB "
          f"({rt.family} family)")
    print(f"prefill: {m['prefill_tok_s']:.0f} tok/s  "
          f"decode: {m['decode_tok_s']:.1f} tok/s")
    print(f"generated ids[0,:16]: {out[0, :16].tolist()}")
    return out


if __name__ == "__main__":
    main()
