"""Serving launcher: prefill a prompt batch, decode with sampling.

  python -m repro.launch.serve --arch qwen3-0.6b --reduced --gen 32 --batch 4

With --quant binary|ternary the trained-master tree is exported ONCE into
packed `QTensor`s (core/qtensor.py) and prefill/decode stream the packed
codes through the Pallas kernel via `qmatmul` — the reported packed MB is
the memory the decode loop actually reads, not an analytic estimate.  On a
pod the same entry point runs under the production mesh with the decode-time
cache shardings from launch/sharding.py.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import decode_context
from repro.core.qtensor import export_packed, tree_nbytes
from repro.core.quantize import QuantSpec
from repro.models import transformer as T
from repro.serve.sampler import sample


def packed_model_bytes(qparams) -> tuple[int, int]:
    """(fp32-equivalent bytes, actual bytes) of an exported serving tree —
    measured from the real `QTensor.nbytes`, not the analytic formula."""
    return tree_nbytes(qparams)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--quant", default="ternary",
                    choices=("none", "binary", "ternary"))
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--top-k", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = cfg.with_quant(QuantSpec(mode=args.quant, norm="channel")
                         if args.quant != "none" else QuantSpec(mode="none"))

    key = jax.random.PRNGKey(args.seed)
    params = T.model_init(key, cfg)
    if args.quant != "none":
        # the train->serve handoff: masters -> packed QTensors, once.  The
        # decode loop below runs against THIS tree, so the printed packed MB
        # is what the matmuls stream.
        params = export_packed(params, cfg.quant)
        fp, packed = packed_model_bytes(params)
        print(f"model bytes: fp32 {fp/1e6:.1f} MB -> packed({args.quant}) "
              f"{packed/1e6:.1f} MB ({fp/packed:.1f}x smaller)")

    B, S = args.batch, args.prompt_len
    ctx, src = decode_context(cfg, S + args.gen)
    extras = {}
    if cfg.family == "vlm":
        extras["img"] = jax.random.normal(key, (B, cfg.n_img_tokens, cfg.d_model))
        src = cfg.n_img_tokens
    if cfg.family == "audio":
        extras["enc_frames"] = jax.random.normal(key, (B, S, cfg.d_model))

    prompt = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0, cfg.vocab)
    caches = T.init_caches(cfg, B, S + args.gen, src_len=src,
                           dtype=jnp.dtype(cfg.dtype))

    prefill = jax.jit(lambda p, t, c: T.prefill(p, t, c, cfg, **extras))
    decode = jax.jit(lambda p, t, c: T.decode_step(p, t, c, cfg))

    t0 = time.perf_counter()
    logits, caches = prefill(params, prompt, caches)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    toks = []
    skey = jax.random.fold_in(key, 2)
    t0 = time.perf_counter()
    for i in range(args.gen):
        skey, sk = jax.random.split(skey)
        nxt = sample(logits, sk, temperature=args.temperature,
                     top_k=args.top_k, vocab=cfg.vocab)
        toks.append(np.asarray(nxt))
        logits, caches = decode(params, nxt, caches)
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    out = np.stack(toks, axis=1)
    print(f"prefill: {B * S / t_prefill:.0f} tok/s  "
          f"decode: {B * args.gen / t_decode:.1f} tok/s")
    print(f"generated ids[0,:16]: {out[0, :16].tolist()}")
    return out


if __name__ == "__main__":
    main()
