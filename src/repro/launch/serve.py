"""Serving launcher: prefill a prompt batch, decode with sampling.

  python -m repro.launch.serve --arch qwen3-0.6b --reduced --gen 32 --batch 4
  python -m repro.launch.serve --arch rnn-paper --quant ternary

Every arch — the transformer pool AND the paper's own BN-LSTM — runs the
same prefill → sample → decode loop through the unified recurrent runtime
(serve/recurrent.py).  With --quant binary|ternary the trained-master tree
is exported ONCE into packed `QTensor`s (core/qtensor.py) and prefill/decode
stream the packed codes through the Pallas kernels — the reported packed MB
is the memory the decode loop actually reads, not an analytic estimate.
For --arch rnn-paper the per-step work is the fused Pallas decode-step
kernel (kernels/decode_step.py): one launch per layer per token.  On a pod
the same entry point runs under the production mesh with the decode-time
cache shardings from launch/sharding.py.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import (ARCH_IDS, RNN_ARCH_IDS, get_config, get_rnn_config,
                           rnn_paper)
from repro.core import bnlstm as BL
from repro.core.qtensor import export_packed, tree_nbytes
from repro.core.quantize import QuantSpec
from repro.models import transformer as T
from repro.serve.recurrent import (RNNRuntime, TransformerRuntime,
                                   drive_session)


def packed_model_bytes(qparams) -> tuple[int, int]:
    """(fp32-equivalent bytes, actual bytes) of an exported serving tree —
    measured from the real `QTensor.nbytes`, not the analytic formula."""
    return tree_nbytes(qparams)


def _report_bytes(rt, quant: str) -> None:
    fp, packed = rt.param_nbytes()
    print(f"model bytes: fp32 {fp/1e6:.1f} MB -> packed({quant}) "
          f"{packed/1e6:.1f} MB ({fp/packed:.1f}x smaller)")


def _build_rnn(args, key):
    """The paper's BN-LSTM/GRU behind the same serving loop."""
    cfg = get_rnn_config(args.arch)
    if args.reduced:
        cfg = rnn_paper.reduced(cfg)
    spec = (QuantSpec(mode=args.quant, norm="batch")
            if args.quant != "none" else QuantSpec(mode="none"))
    cfg = dataclasses.replace(cfg, quant=spec)
    var = BL.rnn_lm_init(key, cfg)
    params = var["params"]
    if args.quant != "none":
        params = BL.export_packed_rnn(params, cfg)
    rt = RNNRuntime(cfg, {"params": params, "state": var["state"]})
    if args.quant != "none":
        _report_bytes(rt, args.quant)
    return cfg, rt


def _build_transformer(args, key):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = cfg.with_quant(QuantSpec(mode=args.quant, norm="channel")
                         if args.quant != "none" else QuantSpec(mode="none"))
    params = T.model_init(key, cfg)
    if args.quant != "none":
        # the train->serve handoff: masters -> packed QTensors, once.  The
        # decode loop below runs against THIS tree, so the printed packed MB
        # is what the matmuls stream.
        params = export_packed(params, cfg.quant)
    B, S = args.batch, args.prompt_len
    extras = {}
    if cfg.family == "vlm":
        extras["img"] = jax.random.normal(key, (B, cfg.n_img_tokens, cfg.d_model))
    if cfg.family == "audio":
        extras["enc_frames"] = jax.random.normal(key, (B, S, cfg.d_model))
    rt = TransformerRuntime(cfg, params, extras=extras)
    if args.quant != "none":
        _report_bytes(rt, args.quant)
    return cfg, rt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS + RNN_ARCH_IDS,
                    default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--quant", default="ternary",
                    choices=("none", "binary", "ternary"))
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--top-k", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    key = jax.random.PRNGKey(args.seed)
    build = _build_rnn if args.arch in RNN_ARCH_IDS else _build_transformer
    cfg, rt = build(args, key)

    B, S = args.batch, args.prompt_len
    prompt = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0, cfg.vocab)
    out, m = drive_session(rt, prompt, cfg.vocab, gen=args.gen,
                           temperature=args.temperature, top_k=args.top_k,
                           seed=args.seed + 1)
    print(f"session state: {m['state_nbytes']/1e6:.2f} MB "
          f"({rt.family} family)")
    print(f"prefill: {m['prefill_tok_s']:.0f} tok/s  "
          f"decode: {m['decode_tok_s']:.1f} tok/s")
    print(f"generated ids[0,:16]: {out[0, :16].tolist()}")
    return out


if __name__ == "__main__":
    main()
