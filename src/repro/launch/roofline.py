"""Roofline analysis from compiled dry-run artifacts (EXPERIMENTS.md §Roofline).

Terms (per chip, seconds) for TPU v5e:

  compute    = HLO_FLOPs / peak_FLOPs          peak = 197 TFLOP/s bf16
  memory     = HLO_bytes / HBM_bw              HBM  = 819 GB/s
  collective = wire_bytes / link_bw            ICI  = ~50 GB/s/link

`cost_analysis()` already reports per-device FLOPs/bytes for the partitioned
module.  Collective wire bytes are NOT in cost_analysis: we parse the
compiled HLO text, take each collective op's per-device result bytes and
apply the ring-algorithm wire factor for its replica-group size g:

  all-reduce     2 * S * (g-1)/g     all-gather      S * (g-1)/g   (S = result)
  reduce-scatter S_in * (g-1)/g      all-to-all      S * (g-1)/g
  collective-permute  S

MODEL_FLOPS uses the 6ND (train) / 2ND (inference) convention with N =
active parameters; the ratio MODEL_FLOPS / HLO_FLOPs exposes remat/dispatch
overhead (a healthy train step with full remat sits near 0.75 = 6/8th... i.e.
1/ratio counts the extra recompute; MoE capacity slack and attention flops
push it further down).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
                "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([\d,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_wire_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-chip wire bytes by collective kind, ring-algorithm accounting."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, op = m.groups()
        size = _shape_bytes(dtype, dims)
        g = 1
        gm = _GROUP_RE.search(line)
        if gm:
            g = int(gm.group(2))
        if g <= 1:
            continue
        frac = (g - 1) / g
        if op == "all-reduce":
            wire = 2.0 * size * frac
        elif op == "collective-permute":
            wire = float(size)
        else:  # all-gather / reduce-scatter / all-to-all
            wire = size * frac
        out[op] = out.get(op, 0.0) + wire
    return out


@dataclasses.dataclass
class Roofline:
    flops: float              # per chip
    hbm_bytes: float          # per chip — XLA 'bytes accessed' (upper bound:
                              # fusion-blind, counts every intermediate)
    wire_bytes: float         # per chip
    collectives: Dict[str, float]
    model_flops: float        # per chip (6ND or 2ND / n_chips)
    hbm_bytes_model: float = 0.0  # analytic HBM traffic (see analytic_hbm_bytes)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory_xla(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_memory(self) -> float:
        """Analytic model when available (the XLA metric has no fusion on
        the CPU pipeline and overstates TPU HBM traffic several-fold)."""
        return (self.hbm_bytes_model or self.hbm_bytes) / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.wire_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flop_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved IF the step runs at the
        bound: (model_flops / peak) / bound_time — the §Perf score basis."""
        if self.bound_time == 0:
            return 0.0
        return (self.model_flops / PEAK_FLOPS) / self.bound_time

    def to_json(self) -> dict:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "hbm_bytes_model_per_chip": self.hbm_bytes_model,
            "wire_bytes_per_chip": self.wire_bytes,
            "collectives": self.collectives,
            "model_flops_per_chip": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_memory_xla_s": self.t_memory_xla,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "useful_flop_ratio": self.useful_flop_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


# ---------------------------------------------------------------------------
# analytic parameter / FLOP counts per architecture
# ---------------------------------------------------------------------------


def param_counts(cfg) -> tuple[float, float]:
    """(total, active) parameter counts from the config (quantizable matmul
    weights + embeddings; norms/bias omitted — O(d) noise)."""
    d, ff, V = cfg.d_model, cfg.d_ff, cfg.padded_vocab
    hd = cfg.hd
    attn = d * cfg.n_heads * hd + 2 * d * cfg.n_kv * hd + cfg.n_heads * hd * d
    if cfg.family == "ssm":  # rwkv6: 5 square tm + channel mix
        per_layer = 5 * d * d + (2 * d * ff + d * d)
        total = cfg.n_layers * per_layer
        active = total
    elif cfg.family == "hybrid":
        di = cfg.d_inner
        N = cfg.ssm_state
        H = cfg.ssm_heads
        mamba = d * (2 * di + 2 * N + H) + di * d
        shared = attn + 3 * d * ff
        total = cfg.n_layers * mamba + shared
        napp = cfg.n_layers // max(cfg.attn_every, 1)
        active = cfg.n_layers * mamba + napp * shared
    else:
        if cfg.n_experts > 0:
            moe = cfg.n_experts * 3 * d * ff
            act_moe = cfg.topk * 3 * d * ff
            total_layer = attn + moe
            active_layer = attn + act_moe
        elif cfg.mlp == "gelu":
            total_layer = active_layer = attn + 2 * d * ff
        else:
            total_layer = active_layer = attn + 3 * d * ff
        n_dec = cfg.n_layers
        total = n_dec * total_layer
        active = n_dec * active_layer
        if cfg.family == "audio":
            enc_layer = attn + 2 * d * ff
            total += cfg.n_enc_layers * enc_layer
            active += cfg.n_enc_layers * enc_layer
            total += n_dec * (attn + 2 * d * ff) - n_dec * 0  # cross attn per dec layer
            active += n_dec * attn  # xattn
        if cfg.family == "vlm":
            pass  # cross layers already counted via pattern share
    emb = V * d * (1 if cfg.tie_embeddings else 2)
    return total + emb, active + emb


def analytic_hbm_bytes(cfg, shape, n_chips: int, *, weight_bits: float = 16,
                       act_bytes: int = 2) -> float:
    """Per-chip HBM traffic model (documented in EXPERIMENTS.md §Roofline).

    train  = 3 weight streams (fwd + bwd + remat recompute) of the ACTIVE
             bf16 compute weights, + optimizer sweep over the fp32 master/
             m/v shards (7 fp32 passes of TOTAL params, FSDP-sharded), +
             activation checkpoints (layer boundaries, write+read), + KV
             materialization (write+read per layer).
    prefill = 1 weight stream + KV write + causal KV re-reads (chunked:
             each of S/chunk chunks reads ~half the KV written so far).
    decode  = 1 weight-shard stream per token + full KV-cache shard read.

    `weight_bits` models the paper's packed-weight serving path (2 for
    ternary, 1 for binary, 16 for bf16) — the decode weight stream shrinks
    by 16x/32x, which is the TPU translation of the paper's 12x memory-
    bandwidth claim.
    """
    total, active = param_counts(cfg)
    wbytes = active * weight_bits / 8.0
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    n_layers_eff = cfg.n_layers + (cfg.n_enc_layers or 0)

    if cfg.family == "audio":
        from repro.configs.shapes import whisper_dec_len
        dec = whisper_dec_len(S)
        tokens, kv_tokens = B * dec, B * S
    else:
        tokens, kv_tokens = B * S, B * S

    kv_layer_bytes = 2 * cfg.n_kv * cfg.hd * act_bytes  # per token per layer

    if shape.kind == "train":
        w_stream = 3.0 * wbytes
        opt = 7.0 * total * 4.0
        act_ckpt = 2.0 * n_layers_eff * tokens * d * act_bytes
        kv = 2.0 * n_layers_eff * kv_tokens * kv_layer_bytes
        return (w_stream + opt + act_ckpt + kv) / n_chips
    if shape.kind == "prefill":
        n_chunks = max(S // max(cfg.attn_chunk, 1), 1)
        kv_write = n_layers_eff * kv_tokens * kv_layer_bytes
        kv_read = kv_write * n_chunks / 2.0
        act = n_layers_eff * tokens * d * act_bytes
        return (wbytes + kv_write + kv_read + act) / n_chips
    # decode: one token; window layers cap their cache reads
    kv_read = 0.0
    from repro.models.transformer import expand_pattern
    pat, rep, tail = expand_pattern(cfg)
    kinds = list(pat) * rep + list(tail)
    for k in kinds:
        if k in ("mamba", "rwkv"):
            if cfg.family == "hybrid":
                di, N = cfg.d_inner, cfg.ssm_state
                kv_read += B * (di // cfg.ssm_headdim) * N * cfg.ssm_headdim * 4
            else:
                H = cfg.d_model // cfg.hd
                kv_read += B * H * cfg.hd * cfg.hd * 4
        elif k == "cross":
            kv_read += B * (cfg.n_img_tokens or S) * kv_layer_bytes
        else:
            ctx = min(cfg.window, S) if (k == "local" or cfg.swa_all) and \
                cfg.window else S
            if cfg.family == "audio":
                ctx = min(448, S)
                kv_read += B * S * kv_layer_bytes  # cross-KV over enc frames
            kv_read += B * ctx * kv_layer_bytes
    return (wbytes + kv_read) / n_chips


def model_flops(cfg, shape, n_chips: int) -> float:
    """Per-chip MODEL_FLOPS: 6·N_active·D train, 2·N_active·D inference."""
    _, active = param_counts(cfg)
    if shape.kind == "train":
        if cfg.family == "audio":
            from repro.configs.shapes import whisper_dec_len
            D = shape.global_batch * whisper_dec_len(shape.seq_len)
        else:
            D = shape.global_batch * shape.seq_len
        return 6.0 * active * D / n_chips
    if shape.kind == "prefill":
        D = shape.global_batch * shape.seq_len
        return 2.0 * active * D / n_chips
    # decode: one token per sequence
    return 2.0 * active * shape.global_batch / n_chips


def build(cell: dict, cfg, shape, n_chips: int,
          weight_bits: float = 16) -> Roofline:
    colls = cell.get("collectives", {})
    return Roofline(
        flops=cell.get("flops", 0.0),
        hbm_bytes=cell.get("bytes_accessed", 0.0),
        wire_bytes=sum(colls.values()),
        collectives=colls,
        model_flops=model_flops(cfg, shape, n_chips),
        hbm_bytes_model=analytic_hbm_bytes(cfg, shape, n_chips,
                                           weight_bits=weight_bits),
    )
