"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — 'pod' is an
outer data-parallel axis whose gradient all-reduce crosses DCN.

Defined as functions (not module constants) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first init.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — run "
            f"under dryrun.py (it forces 512 host devices) or on the pod")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh(model: int = 1):
    """Mesh over whatever is locally available (tests, CPU).

    `model` must divide the local device count.  This used to gcd-shrink the
    model axis silently, which meant `make_host_mesh(model=4)` on 6 devices
    handed back a model=2 mesh and tensor-parallel tests quietly ran at half
    the requested width — now it raises and names the shape the fallback
    would have produced.
    """
    n = len(jax.devices())
    if model < 1:
        raise ValueError(f"model={model} must be >= 1")
    if n % model:
        g = math.gcd(model, n)
        raise ValueError(
            f"model={model} does not divide the {n} local devices; the old "
            f"silent fallback would have built a data={n // g},model={g} "
            f"mesh — pass model={g} explicitly if that is what you want")
    data = n // model
    if model > 1:
        return jax.make_mesh((data, model), ("data", "model"))
    return jax.make_mesh((n,), ("data",))


def parse_mesh_spec(spec: str) -> dict:
    """``"data=4,model=2"`` → ``{"data": 4, "model": 2}`` (absent axes = 1)."""
    sizes = {"data": 1, "model": 1}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            raise ValueError(f"bad mesh spec part {part!r} (want axis=N)")
        axis, _, num = part.partition("=")
        axis = axis.strip()
        if axis not in sizes:
            raise ValueError(
                f"unknown mesh axis {axis!r} (serving meshes have data, model)")
        sizes[axis] = int(num)
        if sizes[axis] < 1:
            raise ValueError(f"mesh axis {axis}={sizes[axis]} must be >= 1")
    return sizes


def make_serve_mesh(spec: str):
    """Serving mesh for ``--mesh data=D,model=M`` over the first D*M local
    devices.  Both axes always exist (size-1 axes are fine — the sharding
    rules' divisibility gates treat them as replication), so one code path
    in the engine covers DP-only, TP-only, and DP×TP."""
    sizes = parse_mesh_spec(spec)
    d, m = sizes["data"], sizes["model"]
    devices = jax.devices()
    if d * m > len(devices):
        raise ValueError(
            f"mesh data={d},model={m} needs {d * m} devices, have "
            f"{len(devices)} — on CPU run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N")
    return jax.make_mesh((d, m), ("data", "model"), devices=devices[: d * m])
