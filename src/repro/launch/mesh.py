"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — 'pod' is an
outer data-parallel axis whose gradient all-reduce crosses DCN.

Defined as functions (not module constants) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first init.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — run "
            f"under dryrun.py (it forces 512 host devices) or on the pod")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh(model: int = 1):
    """Best-effort mesh over whatever is locally available (tests, CPU)."""
    n = len(jax.devices())
    model = math.gcd(model, n)
    data = n // model
    if model > 1:
        return jax.make_mesh((data, model), ("data", "model"))
    return jax.make_mesh((n,), ("data",))
