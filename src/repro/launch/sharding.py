"""Parameter / state / cache PartitionSpec rules for the production mesh.

Weight layout (DESIGN.md §4): TP over 'model' (column-parallel up-projections,
row-parallel down-projections, expert axis for MoE, vocab axis for embedding
and head), FSDP over 'data' on the other matmul dim.  XLA SPMD then emits the
ZeRO-3-style all-gather-on-use + reduce-scatter-on-grad schedule.  Axes that
do not divide a dimension are dropped (replicated) so one rule set serves
every (arch x mesh) cell.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.qtensor import is_qtensor
from repro.models.mamba2 import SSMState
from repro.models.rwkv6 import RWKVState
from repro.serve.kvcache import AttnCache, CrossCache, kv_pspec, slot_axis
from repro.runtime import use_mesh

# row-parallel (input dim on 'model'): projections whose input is the
# model-sharded hidden (attention heads / ffn hidden / ssm inner).
ROW_W = {"Wo", "Wdown", "Wfc2", "Wout", "Wcv"}


def _fit(dim: int, axis: str, mesh: Mesh) -> Optional[str]:
    n = mesh.shape.get(axis, 1)
    return axis if n > 1 and dim % n == 0 else None


def _key_str(p) -> str:
    for attr in ("key", "idx", "name"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def param_pspec(path, leaf, mesh: Mesh) -> P:
    keys = [_key_str(p) for p in path]
    name = keys[-1] if keys else ""
    shape = leaf.shape
    nd = len(shape)

    if name == "embed":
        return P(_fit(shape[0], "model", mesh), _fit(shape[1], "data", mesh))
    if name == "head":
        return P(_fit(shape[0], "data", mesh), _fit(shape[1], "model", mesh))
    if name.startswith("W") and nd >= 2:
        lead = [None] * (nd - 2)
        if "moe" in keys and nd >= 3:
            # (.., E, din, dout): expert-parallel over 'model', FSDP over
            # 'data'.  When E doesn't divide the model axis (mixtral: 8
            # experts, 16-way TP) fall back to tensor parallelism INSIDE the
            # experts (shard d_ff over 'model'), matching moe_apply's einsums.
            lead = [None] * (nd - 3)
            e_ax = _fit(shape[-3], "model", mesh)
            if e_ax is not None:
                return P(*lead, e_ax, _fit(shape[-2], "data", mesh), None)
            if name in ROW_W:  # (E, f, d): f on model, d on data
                return P(*lead, None, _fit(shape[-2], "model", mesh),
                         _fit(shape[-1], "data", mesh))
            return P(*lead, None, _fit(shape[-2], "data", mesh),
                     _fit(shape[-1], "model", mesh))
        if name in ROW_W:
            return P(*lead, _fit(shape[-2], "model", mesh),
                     _fit(shape[-1], "data", mesh))
        return P(*lead, _fit(shape[-2], "data", mesh),
                 _fit(shape[-1], "model", mesh))
    # 1D / small parameters: replicated
    return P()


def param_shardings(params: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_pspec(path, leaf, mesh)),
        params)


def _drop(spec: P, axes=("data", "pod")) -> P:
    def keep(a):
        if a is None:
            return None
        if isinstance(a, tuple):
            kept = tuple(x for x in a if x not in axes)
            return kept if len(kept) > 1 else (kept[0] if kept else None)
        return None if a in axes else a

    return P(*[keep(a) for a in spec])


def serve_param_pspec(path, leaf, mesh: Mesh) -> P:
    """Serving layout: tensor-parallel only (weights replicated across the
    data axes) — no optimizer shards to co-locate with, and dropping the
    FSDP axis removes the per-token weight all-gather from the decode step."""
    return _drop(param_pspec(path, leaf, mesh))


def compute_param_pspec(path, leaf, mesh: Mesh) -> P:
    """Layout of the transient COMPUTE copy of a weight (bf16 / unpacked):
    what its matmul actually consumes — the storage layout minus the FSDP
    axes (TP sharding kept).

    Note (§Perf, refuted hypothesis): for non-divisible MoE experts we tried
    returning P() (fully replicated) so the model-axis reshard would also
    ride the packed codes; measured wire went UP 48% (9.8s vs 6.7s) because
    SPMD then re-materialized full-size gathers at the dot's convert — the
    capacity-sharded expert einsums genuinely want f-sharded weights."""
    return _drop(param_pspec(path, leaf, mesh))


def qtensor_pspecs(spec: P, q, mesh: Mesh):
    """Project a dense-layout spec for QTensor `q`'s LOGICAL shape (..., K, N)
    onto its packed codes (..., ceil(K/G), N).

    The output-column axis carries over unchanged — packing preserves the
    column count, so column-parallel QTensors shard exactly like their dense
    masters.  The contraction axis keeps its entry only when the PACKED row
    count still divides the mesh axes AND packing needed no pad rows (K a
    multiple of the pack group) — otherwise a shard boundary would fall
    inside a pack word, or inside dequantize's pad-slice, and XLA would
    reshard the codes on first use.  Leading (stack / expert) entries carry
    over unchanged.  Returns (codes_spec, scale_spec); a per-output-channel
    scale follows the column entry.
    """
    nd = q.codes.ndim
    entries = list(tuple(spec)) + [None] * (nd - len(tuple(spec)))
    entries = entries[:nd]
    k_ax = nd - 2
    ke = entries[k_ax]
    if ke is not None:
        axes = ke if isinstance(ke, tuple) else (ke,)
        parts = math.prod(mesh.shape.get(a, 1) for a in axes)
        padded = q.codes.shape[k_ax] * q.group != q.k
        if padded or parts < 2 or q.codes.shape[k_ax] % parts:
            entries[k_ax] = None
    codes_spec = P(*entries)
    scale_spec = None
    if q.scale is not None:
        ce = entries[-1] if q.scale.shape[-1] == q.codes.shape[-1] else None
        scale_spec = P(*([None] * (q.scale.ndim - 1)), ce)
    return codes_spec, scale_spec


def serve_param_shardings(params: Any, mesh: Mesh) -> Any:
    """QTensor-aware serving shardings.  Packed leaves report their logical
    (..., K, N) via QTensor.shape, so the name-based rules apply unchanged;
    the resulting dense spec is then projected onto codes/scale.  The return
    leaf for a packed weight is a QTensor whose children are NamedShardings —
    the same treedef as the value tree, which is what jax.device_put and
    jit in_shardings expect for a registered dataclass."""

    def one(path, leaf):
        spec = serve_param_pspec(path, leaf, mesh)
        if not is_qtensor(leaf):
            return NamedSharding(mesh, spec)
        cs, ss = qtensor_pspecs(spec, leaf, mesh)
        return dataclasses.replace(
            leaf,
            codes=NamedSharding(mesh, cs),
            scale=None if ss is None else NamedSharding(mesh, ss))

    return jax.tree_util.tree_map_with_path(one, params, is_leaf=is_qtensor)


def state_shardings(state: Any, mesh: Mesh) -> Any:
    """TrainState shardings: params/opt-moments/residual follow param rules,
    scalars and rng replicated, RNN bn_state replicated (O(d) vectors)."""
    pshard = param_shardings(state.params, mesh)
    rep = NamedSharding(mesh, P())
    rep_tree = lambda t: jax.tree.map(lambda _: rep, t)
    return state._replace(
        params=pshard,
        opt=state.opt._replace(
            step=rep,
            m=pshard if state.opt.m is not None else None,
            v=pshard if state.opt.v is not None else None,
        ),
        rng=rep,
        bn_state=rep_tree(state.bn_state) if state.bn_state is not None else None,
        residual=pshard if state.residual is not None else None,
    )


def batch_shardings(batch: Any, mesh: Mesh) -> Any:
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    spec = axes if len(axes) > 1 else (axes[0] if axes else None)

    def one(x):
        b = spec
        import math
        n = math.prod(mesh.shape[a] for a in axes) if axes else 1
        if n > 1 and x.shape[0] % n != 0:
            b = None
        return NamedSharding(mesh, P(b, *([None] * (len(x.shape) - 1))))

    return jax.tree.map(one, batch)


def _bd(mesh: Mesh, batch: int):
    axes, prod = [], 1
    for a in ("pod", "data"):
        n = mesh.shape.get(a, 1)
        if n > 1 and batch % (prod * n) == 0:
            axes.append(a)
            prod *= n
    return tuple(axes) if len(axes) > 1 else (axes[0] if axes else None)


def cache_shardings(caches: Any, mesh: Mesh) -> Any:
    """Walk the cache pytree (AttnCache/CrossCache/SSMState/RWKVState nodes
    possibly stacked with a leading repeat axis) and assign specs."""
    m = mesh.shape.get("model", 1)
    rep = NamedSharding(mesh, P())

    def kv_like(shape):  # (.., B, C, H, hd)
        lead = [None] * (len(shape) - 4)
        B, C, H = shape[-4], shape[-3], shape[-2]
        with use_mesh(mesh):
            spec = kv_pspec(B, C, H)
        return NamedSharding(mesh, P(*lead, *spec))

    def node(c):
        if isinstance(c, AttnCache):
            s = kv_like(c.k.shape)
            return AttnCache(k=s, v=s, pos=rep, ring=c.ring)
        if isinstance(c, CrossCache):
            s = kv_like(c.k.shape)
            return CrossCache(k=s, v=s)
        if isinstance(c, SSMState):
            lead = [None] * (c.h.ndim - 4)
            B, H = c.h.shape[-4], c.h.shape[-3]
            bd = _bd(mesh, B)
            h = NamedSharding(mesh, P(*lead, bd, _fit(H, "model", mesh), None, None))
            conv = NamedSharding(mesh, P(*lead, bd, None,
                                         _fit(c.conv.shape[-1], "model", mesh)))
            return SSMState(h=h, conv=conv, pos=rep)
        if isinstance(c, RWKVState):
            lead = [None] * (c.S.ndim - 4)
            B, H = c.S.shape[-4], c.S.shape[-3]
            bd = _bd(mesh, B)
            S = NamedSharding(mesh, P(*lead, bd, _fit(H, "model", mesh), None, None))
            sh = NamedSharding(mesh, P(*lead, bd, _fit(c.tm_shift.shape[-1], "model", mesh)))
            return RWKVState(S=S, tm_shift=sh, cm_shift=sh, pos=rep)
        raise TypeError(type(c))

    return jax.tree.map(node, caches,
                        is_leaf=lambda x: isinstance(
                            x, (AttnCache, CrossCache, SSMState, RWKVState)))


def serve_pool_shardings(pool: Any, ref: Any, mesh: Mesh) -> Any:
    """NamedShardings for a ServeEngine slot pool.

    The slot axis of every leaf — recovered against the batch-1 `ref`
    template exactly the way the engine's slot surgery does — shards over
    the data axes, so slot s lives on data shard ``s // (slots / D)``
    (NamedSharding splits an axis into equal contiguous blocks in mesh-axis
    order).  AttnCache K/V additionally shard their KV-heads axis over
    'model' when divisible, mirroring ``kv_pspec``'s preferred layout;
    recurrent state (RNN h/c, SSM, RWKV) keeps its feature axes local so
    the elementwise gate math stays shard-local.  Leaves without a slot
    axis (shared scalars) replicate.
    """

    def leaf_sh(p, r, extra=()):
        ax = slot_axis(p.shape, r.shape)
        spec = [None] * len(p.shape)
        if ax is not None:
            spec[ax] = _bd(mesh, p.shape[ax])
        for a, m_ax in extra:
            if a is not None and a < len(p.shape) and spec[a] is None:
                spec[a] = _fit(p.shape[a], m_ax, mesh)
        return NamedSharding(mesh, P(*spec))

    def node(p, r):
        if isinstance(p, (AttnCache, CrossCache)):
            ax = slot_axis(p.k.shape, r.k.shape)
            heads = None if ax is None else ax + 2  # (.., B, C, H, hd)
            kv = leaf_sh(p.k, r.k, extra=((heads, "model"),))
            if isinstance(p, CrossCache):
                return CrossCache(k=kv, v=kv)
            return AttnCache(k=kv, v=kv, pos=leaf_sh(p.pos, r.pos), ring=p.ring)
        return leaf_sh(p, r)

    return jax.tree.map(node, pool, ref,
                        is_leaf=lambda x: isinstance(x, (AttnCache, CrossCache)))
