"""End-to-end driver: train the PAPER's character-level model (BN-LSTM with
ternary recurrent weights, Appendix C hyperparameters scaled to this box) for
a few hundred steps on a real byte corpus (this repository's source tree —
the offline stand-in for Linux-Kernel), with checkpointing and preemption
handling.

  PYTHONPATH=src python examples/train_char_lm.py                 # ~200 steps
  PYTHONPATH=src python examples/train_char_lm.py --hidden 1000 \
      --steps 400 --mode binary                                   # paper scale

Ctrl-C mid-run, then re-run with the same --ckpt-dir: training resumes
exactly (stateless step-indexed data + atomic checkpoints).
"""
import argparse
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bnlstm as BL
from repro.core.quantize import QuantSpec
from repro.data.text import ByteCorpus
from repro.train import checkpoint as CK
from repro.train.fault_tolerance import RESTART_EXIT_CODE, PreemptionHandler
from repro.train.optimizer import OptConfig
from repro.train.train_step import (make_rnn_eval, make_rnn_train_step,
                                    train_state_init)

REPO = Path(__file__).resolve().parents[1]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="ternary",
                    choices=("ternary", "binary", "none", "binaryconnect"))
    ap.add_argument("--hidden", type=int, default=256)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=100)     # paper: 100
    ap.add_argument("--lr", type=float, default=2e-3)   # paper: 0.002, ADAM
    ap.add_argument("--data", default=str(REPO / "src"))
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    corpus = ByteCorpus.from_dir(Path(args.data))
    print(f"corpus: {corpus.data.size / 1e6:.1f}M chars, vocab {corpus.vocab}")

    quant = (QuantSpec(mode="none") if args.mode == "none"
             else QuantSpec(mode=args.mode, norm="batch"))
    cfg = BL.RNNConfig(vocab=corpus.vocab, d_hidden=args.hidden, quant=quant,
                       cell_norm=args.mode != "binaryconnect")
    var = BL.rnn_lm_init(jax.random.PRNGKey(0), cfg)
    opt = OptConfig(kind="adamw", lr=args.lr)
    state = train_state_init(var["params"], opt, jax.random.PRNGKey(1),
                             bn_state=var["state"])
    step = jax.jit(make_rnn_train_step(cfg, opt))
    evaluate = jax.jit(make_rnn_eval(cfg))

    start = 0
    if args.ckpt_dir and CK.latest_step(args.ckpt_dir) is not None:
        start = CK.latest_step(args.ckpt_dir)
        state = CK.restore(state, args.ckpt_dir, start)
        print(f"resumed from step {start}")
    handler = PreemptionHandler()
    ckpt = CK.AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None

    t0 = time.time()
    for i in range(start, args.steps):
        b = {k: jnp.asarray(v) for k, v in
             corpus.batch("train", i, args.batch, args.seq).items()}
        state, m = step(state, b)
        if i % 20 == 0 or i == args.steps - 1:
            vb = {k: jnp.asarray(v) for k, v in
                  corpus.batch("valid", 0, args.batch, args.seq).items()}
            val = evaluate(state, vb)
            print(f"step {i:4d}  train bpc {float(m['bpc']):.3f}  "
                  f"val bpc {float(val['bpc']):.3f}  "
                  f"({(i - start + 1) / (time.time() - t0):.2f} steps/s)",
                  flush=True)
        if ckpt and i and i % 50 == 0:
            ckpt.save_async(state, i)
        if handler.preempted:
            if ckpt:
                ckpt.wait()
                CK.save(state, args.ckpt_dir, i + 1)
            print("preempted — checkpointed, exit 43")
            sys.exit(RESTART_EXIT_CODE)
    if ckpt:
        ckpt.wait()
        CK.save(state, args.ckpt_dir, args.steps)

    # memory footprint at the paper's accounting (Table 1)
    n = corpus.vocab * 4 * args.hidden + args.hidden * 4 * args.hidden
    bits = {"ternary": 2, "binary": 1, "binaryconnect": 1, "none": 32}[args.mode]
    print(f"recurrent weights: fp32 {n * 4 / 1e3:.0f} KB -> "
          f"{args.mode} {n * bits / 8 / 1e3:.0f} KB")


if __name__ == "__main__":
    main()
