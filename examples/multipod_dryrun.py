"""Lower + compile one (arch x shape) cell on the 512-chip multi-pod mesh and
print its memory/cost analysis — the smallest possible demonstration of the
production distribution config.

  PYTHONPATH=src python examples/multipod_dryrun.py llama3-8b decode_32k
"""
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

if __name__ == "__main__":
    arch = sys.argv[1] if len(sys.argv) > 1 else "qwen3-0.6b"
    shape = sys.argv[2] if len(sys.argv) > 2 else "decode_32k"
    # dryrun must own the process (XLA device-count flag before jax init)
    raise SystemExit(subprocess.call(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "multi", "--out",
         str(REPO / "results" / "dryrun")],
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
        cwd=REPO))
