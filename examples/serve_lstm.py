"""Packed serving for the paper's BN-LSTM: train -> export -> decode 2-bit.

  PYTHONPATH=src python examples/serve_lstm.py
  PYTHONPATH=src python examples/serve_lstm.py --mode binary --steps 60

The train->deploy handoff the paper is about, on its own model:

1. train a small BN-LSTM with stochastic ternary (or binary) recurrent
   weights for a few steps on a synthetic byte corpus,
2. `export_packed_rnn` the masters into packed `QTensor`s — 2-bit/1-bit
   codes, the artifact a deployment ships,
3. generate text STATEFULLY through the unified recurrent runtime
   (serve/recurrent.py): one `prefill` over the prompt, then one
   `decode_step` per token — on accelerators the WHOLE tick (every layer's
   accumulation-only GEMV + BN affine + gates, plus the logits head) is a
   single fused Pallas launch; on CPU the same packed artifact serves
   through the compiled dense fallback (DESIGN.md §11) — with O(1) state
   instead of re-running the whole sequence,
4. verify the stepwise decode matches the full-sequence `rnn_lm_apply`
   against the same packed tree.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bnlstm as BL
from repro.core.qtensor import is_qtensor, tree_nbytes
from repro.core.quantize import QuantSpec
from repro.data.synth import markov_bytes
from repro.serve.recurrent import RNNRuntime, state_nbytes
from repro.train.optimizer import OptConfig
from repro.train.train_step import make_rnn_train_step, train_state_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="ternary", choices=("ternary", "binary"))
    ap.add_argument("--hidden", type=int, default=128)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--gen", type=int, default=48)
    args = ap.parse_args()

    data = np.asarray(markov_bytes(200_000, vocab=64, seed=0))
    vocab = 64

    cfg = BL.RNNConfig(vocab=vocab, d_hidden=args.hidden,
                       quant=QuantSpec(mode=args.mode, norm="batch"))
    var = BL.rnn_lm_init(jax.random.PRNGKey(0), cfg)
    state = train_state_init(var["params"], OptConfig(kind="adamw", lr=2e-3),
                             jax.random.PRNGKey(1), bn_state=var["state"])
    step = jax.jit(make_rnn_train_step(cfg, OptConfig(kind="adamw", lr=2e-3)))

    # -- 1. train ------------------------------------------------------------
    rng = np.random.default_rng(0)
    for i in range(args.steps):
        starts = rng.integers(0, data.size - args.seq - 1, size=args.batch)
        toks = np.stack([data[s: s + args.seq + 1] for s in starts])
        batch = {"tokens": jnp.asarray(toks[:, :-1]),
                 "targets": jnp.asarray(toks[:, 1:])}
        state, metrics = step(state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:3d}  bpc {float(metrics['bpc']):.3f}")

    # -- 2. export: masters -> packed QTensors -------------------------------
    qparams = BL.export_packed_rnn(state.params, cfg)
    n_packed = sum(is_qtensor(l) for l in jax.tree_util.tree_leaves(
        qparams, is_leaf=is_qtensor))
    fp, real = tree_nbytes(qparams)
    print(f"exported {n_packed} packed weights: fp32 {fp/1e3:.0f} KB -> "
          f"{args.mode} {real/1e3:.0f} KB ({fp/real:.1f}x smaller)")

    packed_vars = {"params": qparams, "state": state.bn_state}

    # -- 3. stateful decode against the packed tree ---------------------------
    # prefill once, then O(1)-state decode steps: each step is the fused
    # Pallas decode kernel, not a re-run of the growing sequence.
    rt = RNNRuntime(cfg, packed_vars)
    prompt = jnp.asarray(data[: args.seq][None, :])
    st = rt.init_state(batch=1)
    logits, st = rt.prefill(prompt, st)
    print(f"session state: {state_nbytes(st) / 1e3:.1f} KB "
          f"(constant — no KV cache growth)")
    out = []
    for _ in range(args.gen):
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(int(nxt[0]))
        logits, st = rt.decode_step(nxt, st)
    print(f"greedy continuation ids[:16]: {out[:16]}")

    # -- 4. parity: stepwise decode == full-sequence forward ------------------
    probe = jnp.asarray(data[1000: 1000 + args.seq][None, :])
    lg_full = BL.rnn_lm_apply(packed_vars, probe, cfg, training=False)
    lg_pre, st2 = BL.rnn_prefill(packed_vars, probe[:, :-1], cfg)
    lg_last, _ = BL.rnn_decode_step(packed_vars, probe[:, -1], cfg, st2)
    np.testing.assert_allclose(np.asarray(lg_pre), np.asarray(lg_full[:, :-1]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(lg_last), np.asarray(lg_full[:, -1]),
                               rtol=2e-4, atol=2e-4)
    print("stateful prefill/decode matches the full-sequence forward ✓")
    return out


if __name__ == "__main__":
    main()
