"""Packed serving for the paper's BN-LSTM: train -> export -> decode 2-bit.

  PYTHONPATH=src python examples/serve_lstm.py
  PYTHONPATH=src python examples/serve_lstm.py --mode binary --steps 60

The train->deploy handoff the paper is about, on its own model:

1. train a small BN-LSTM with stochastic ternary (or binary) recurrent
   weights for a few steps on a synthetic byte corpus,
2. `export_packed_rnn` the masters into packed `QTensor`s — 2-bit/1-bit
   codes, the artifact a deployment ships,
3. generate text running `rnn_lm_apply` UNCHANGED against the packed tree:
   every recurrent matmul streams uint32 codes through the Pallas packed
   kernel (interpret mode on CPU) via `kernels.ops.qmatmul`,
4. verify the packed logits match the deterministic fp quantization path.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bnlstm as BL
from repro.core.qtensor import is_qtensor, tree_nbytes
from repro.core.quantize import QuantSpec
from repro.data.synth import markov_bytes
from repro.train.optimizer import OptConfig
from repro.train.train_step import make_rnn_train_step, train_state_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="ternary", choices=("ternary", "binary"))
    ap.add_argument("--hidden", type=int, default=128)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--gen", type=int, default=48)
    args = ap.parse_args()

    data = np.asarray(markov_bytes(200_000, vocab=64, seed=0))
    vocab = 64

    cfg = BL.RNNConfig(vocab=vocab, d_hidden=args.hidden,
                       quant=QuantSpec(mode=args.mode, norm="batch"))
    var = BL.rnn_lm_init(jax.random.PRNGKey(0), cfg)
    state = train_state_init(var["params"], OptConfig(kind="adamw", lr=2e-3),
                             jax.random.PRNGKey(1), bn_state=var["state"])
    step = jax.jit(make_rnn_train_step(cfg, OptConfig(kind="adamw", lr=2e-3)))

    # -- 1. train ------------------------------------------------------------
    rng = np.random.default_rng(0)
    for i in range(args.steps):
        starts = rng.integers(0, data.size - args.seq - 1, size=args.batch)
        toks = np.stack([data[s: s + args.seq + 1] for s in starts])
        batch = {"tokens": jnp.asarray(toks[:, :-1]),
                 "targets": jnp.asarray(toks[:, 1:])}
        state, metrics = step(state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:3d}  bpc {float(metrics['bpc']):.3f}")

    # -- 2. export: masters -> packed QTensors -------------------------------
    qparams = BL.export_packed_rnn(state.params, cfg)
    n_packed = sum(is_qtensor(l) for l in jax.tree_util.tree_leaves(
        qparams, is_leaf=is_qtensor))
    fp, real = tree_nbytes(qparams)
    print(f"exported {n_packed} packed weights: fp32 {fp/1e3:.0f} KB -> "
          f"{args.mode} {real/1e3:.0f} KB ({fp/real:.1f}x smaller)")

    packed_vars = {"params": qparams, "state": state.bn_state}
    fp_vars = {"params": state.params, "state": state.bn_state}

    # -- 3. decode against the packed tree -----------------------------------
    apply_packed = jax.jit(lambda t: BL.rnn_lm_apply(
        packed_vars, t, cfg, training=False))
    seq = jnp.asarray(data[: args.seq][None, :])
    out = []
    for _ in range(args.gen):
        logits = apply_packed(seq)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(seq.dtype)
        out.append(int(nxt[0]))
        seq = jnp.concatenate([seq[:, 1:], nxt[:, None]], axis=1)
    print(f"greedy continuation ids[:16]: {out[:16]}")

    # -- 4. parity: packed serve == deterministic fp quantization ------------
    probe = jnp.asarray(data[1000: 1000 + args.seq][None, :])
    lg_packed = BL.rnn_lm_apply(packed_vars, probe, cfg, training=False)
    lg_fp = BL.rnn_lm_apply(fp_vars, probe, cfg, training=False)
    np.testing.assert_allclose(np.asarray(lg_packed), np.asarray(lg_fp),
                               rtol=2e-4, atol=2e-4)
    print("packed serve matches the fp deterministic-quantization path ✓")
    return out


if __name__ == "__main__":
    main()
