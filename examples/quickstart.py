"""Quickstart: the paper's technique in five minutes.

  PYTHONPATH=src python examples/quickstart.py

1. Stochastically ternarize a weight matrix (Eq. 4-6) with straight-through
   gradients (Eq. 1).
2. Train a small BN-LSTM with ternary recurrent weights (Eq. 7 / Alg. 1) on a
   structured synthetic corpus, watching BPC fall.
3. Pack the trained weights to 2 bits and run the Pallas packed-matmul kernel
   (interpret mode on CPU) — the serving path a TPU would use.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bnlstm as BL
from repro.core import quantize as Q
from repro.core.qtensor import QTensor
from repro.core.quantize import QuantSpec
from repro.data.synth import markov_bytes
from repro.data.text import ByteCorpus
from repro.kernels import ops
from repro.train.optimizer import OptConfig
from repro.train.train_step import make_rnn_train_step, train_state_init

# --- 1. the quantizer --------------------------------------------------------
key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (8, 8)) * 0.05
alpha = Q.glorot_alpha(8, 8)
u = jax.random.uniform(jax.random.fold_in(key, 1), w.shape)
q = Q.ternarize_stochastic(w, u, alpha)
print("master weights:\n", np.round(np.asarray(w[:2]), 3))
print("ternary sample (values in {-a, 0, +a}, a=%.4f):\n" % alpha,
      np.round(np.asarray(q[:2]), 4))

grad = jax.grad(lambda w: jnp.sum(Q.quantize(w, "ternary", alpha, u)))(w)
print("STE gradient is identity:", bool((grad == 1.0).all()))

# --- 2. train a ternary BN-LSTM ----------------------------------------------
corpus = ByteCorpus.from_bytes(
    bytes(bytearray(np.asarray(markov_bytes(50_000, vocab=32, seed=0)) % 256)))
cfg = BL.RNNConfig(vocab=corpus.vocab, d_hidden=96,
                   quant=QuantSpec(mode="ternary", norm="batch"))
var = BL.rnn_lm_init(key, cfg)
state = train_state_init(var["params"], OptConfig(lr=5e-3),
                         jax.random.PRNGKey(1), bn_state=var["state"])
step = jax.jit(make_rnn_train_step(cfg, OptConfig(lr=5e-3)))
for i in range(80):
    batch = {k: jnp.asarray(v) for k, v in
             corpus.batch("train", i, 16, 32).items()}
    state, m = step(state, batch)
    if i % 20 == 0 or i == 79:
        print(f"step {i:3d}  bpc {float(m['bpc']):.3f}  "
              f"(uniform would be {np.log2(corpus.vocab):.2f})")

# --- 3. pack + MAC-free-style matmul ------------------------------------------
wh = state.params["layers"][0]["wh"]          # trained master weights
a = Q.glorot_alpha(*wh.shape)
qt = QTensor.from_master(wh, "ternary", a)    # the serving artifact
x = jax.random.normal(jax.random.PRNGKey(2), (4, wh.shape[0]))
y_packed = ops.qmatmul(x, qt)                 # Pallas packed kernel
y_ref = x @ Q.ternarize_deterministic(wh, a)
print(f"packed weights: {qt.nbytes / 1e3:.1f} KB "
      f"(fp32 would be {wh.size * 4 / 1e3:.1f} KB — "
      f"{wh.size * 4 / qt.nbytes:.0f}x smaller)")
print("packed-kernel matmul max err vs reference:",
      float(jnp.max(jnp.abs(y_packed - y_ref))))
