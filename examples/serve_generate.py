"""Serve a small pool model with batched requests: prefill + sampled decode
through the KV-cache runtime, with ternary (2-bit) weights at runtime.

  PYTHONPATH=src python examples/serve_generate.py
  PYTHONPATH=src python examples/serve_generate.py --arch mixtral-8x7b --gen 16

This is a thin veneer over launch/serve.py — the same entry point that runs
under the production mesh on a pod.
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    args = sys.argv[1:] or ["--arch", "qwen3-0.6b", "--reduced",
                            "--quant", "ternary", "--prompt-len", "24",
                            "--gen", "24", "--batch", "2"]
    main(args)
